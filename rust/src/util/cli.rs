//! A small declarative command-line parser (no `clap` in the offline set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, subcommands, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser for one (sub)command.
#[derive(Clone, Debug)]
pub struct Args {
    program: String,
    about: &'static str,
    opts: Vec<OptSpec>,
    values: BTreeMap<&'static str, String>,
    positionals: Vec<String>,
}

/// Errors produced while parsing the command line.
#[derive(Debug)]
pub enum CliError {
    /// An option that was not declared.
    Unknown(String),
    /// A declared, non-boolean option with no value.
    MissingValue(String),
    /// A required option with no default that was not provided.
    Required(&'static str),
    /// Value failed to parse into the requested type.
    BadValue(&'static str, String, &'static str),
    /// `--help` was requested; the caller should print and exit.
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name} (see --help)"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::Required(name) => write!(f, "required option --{name} not provided"),
            CliError::BadValue(name, raw, ty) => {
                write!(f, "option --{name}: cannot parse {raw:?} as {ty}")
            }
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Start a parser for `program` with a one-line description.
    pub fn new(program: &str, about: &'static str) -> Self {
        Self {
            program: program.to_string(),
            about,
            opts: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required option (no default).
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_bool: false });
        self
    }

    /// Declare a boolean flag (false unless present).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    /// Render the help text.
    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nOptions:");
        for o in &self.opts {
            let kind = if o.is_bool { "" } else { " <value>" };
            let def = match (&o.default, o.is_bool) {
                (Some(d), false) => format!(" [default: {d}]"),
                _ => String::new(),
            };
            let _ = writeln!(s, "  --{}{kind}\n      {}{def}", o.name, o.help);
        }
        s
    }

    /// Parse an iterator of raw arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self, CliError> {
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?
                    .clone();
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next().ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                self.values.insert(spec.name, value);
            } else {
                self.positionals.push(arg);
            }
        }
        // Check required options.
        for o in &self.opts {
            if o.default.is_none() && !self.values.contains_key(o.name) {
                return Err(CliError::Required(o.name));
            }
        }
        Ok(self)
    }

    /// Raw string value of an option (declared default if not given).
    pub fn get(&self, name: &'static str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    /// Typed accessor.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &'static str) -> Result<T, CliError> {
        let raw = self.get(name);
        raw.parse::<T>()
            .map_err(|_| CliError::BadValue(name, raw, std::any::type_name::<T>()))
    }

    /// `usize` accessor.
    pub fn get_usize(&self, name: &'static str) -> Result<usize, CliError> {
        self.get_parse(name)
    }

    /// `u64` accessor.
    pub fn get_u64(&self, name: &'static str) -> Result<u64, CliError> {
        self.get_parse(name)
    }

    /// `f64` accessor.
    pub fn get_f64(&self, name: &'static str) -> Result<f64, CliError> {
        self.get_parse(name)
    }

    /// Boolean flag accessor.
    pub fn get_flag(&self, name: &'static str) -> bool {
        self.get(name) == "true"
    }

    /// Positional arguments in order.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

/// Parse an `"AxB"`-style dimension pair (e.g. `--grid 4x8`); both parts
/// must be positive integers. Returns `None` on any malformed input.
pub fn parse_pair(raw: &str, sep: char) -> Option<(u32, u32)> {
    let (a, b) = raw.split_once(sep)?;
    let a: u32 = a.trim().parse().ok()?;
    let b: u32 = b.trim().parse().ok()?;
    if a == 0 || b == 0 {
        return None;
    }
    Some((a, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("test", "a test command")
            .opt("scale", "18", "graph scale")
            .opt("fanout", "4", "butterfly fanout")
            .flag("verbose", "print more")
            .req("graph", "graph name")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = base().parse(argv(&["--graph", "kron", "--scale=20"])).unwrap();
        assert_eq!(a.get("graph"), "kron");
        assert_eq!(a.get_usize("scale").unwrap(), 20);
        assert_eq!(a.get_usize("fanout").unwrap(), 4);
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn bool_flag_set() {
        let a = base().parse(argv(&["--graph", "g", "--verbose"])).unwrap();
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        let e = base().parse(argv(&["--scale", "10"])).unwrap_err();
        assert!(matches!(e, CliError::Required("graph")));
    }

    #[test]
    fn unknown_option_errors() {
        let e = base().parse(argv(&["--graph", "g", "--bogus", "1"])).unwrap_err();
        assert!(matches!(e, CliError::Unknown(_)));
    }

    #[test]
    fn missing_value_errors() {
        let e = base().parse(argv(&["--graph"])).unwrap_err();
        assert!(matches!(e, CliError::MissingValue(_)));
    }

    #[test]
    fn bad_value_errors() {
        let a = base().parse(argv(&["--graph", "g", "--scale", "xyz"])).unwrap();
        assert!(matches!(a.get_usize("scale"), Err(CliError::BadValue(..))));
    }

    #[test]
    fn positionals_collected() {
        let a = base().parse(argv(&["run", "--graph", "g", "extra"])).unwrap();
        assert_eq!(a.positionals(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn parse_pair_grid_syntax() {
        assert_eq!(parse_pair("4x8", 'x'), Some((4, 8)));
        assert_eq!(parse_pair("1x1", 'x'), Some((1, 1)));
        assert_eq!(parse_pair(" 4 x 8 ", 'x'), Some((4, 8)));
        assert_eq!(parse_pair("4x0", 'x'), None);
        assert_eq!(parse_pair("0x4", 'x'), None);
        assert_eq!(parse_pair("4", 'x'), None);
        assert_eq!(parse_pair("4x8x2", 'x'), None);
        assert_eq!(parse_pair("axb", 'x'), None);
    }

    #[test]
    fn help_requested() {
        let e = base().parse(argv(&["-h"])).unwrap_err();
        assert!(matches!(e, CliError::HelpRequested));
        assert!(base().help_text().contains("--fanout"));
    }
}
