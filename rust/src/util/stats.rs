//! Summary statistics and the paper's root-sampling protocol helpers.

/// Basic summary of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Number of observations.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n−1 denominator; 0 for n<2).
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Median (average of middle two for even n).
    pub median: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample. Panics on an empty slice.
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            median,
            max: sorted[n - 1],
        }
    }
}

/// The paper's benchmarking protocol (§4 Inputs): run many roots, drop the
/// `k` fastest and `k` slowest times, average the remainder.
///
/// Returns the trimmed mean. Panics if `2k >= xs.len()`.
pub fn trimmed_mean(xs: &[f64], k: usize) -> f64 {
    assert!(
        2 * k < xs.len(),
        "trimmed_mean: dropping {} of {} samples",
        2 * k,
        xs.len()
    );
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
    let kept = &sorted[k..xs.len() - k];
    kept.iter().sum::<f64>() / kept.len() as f64
}

/// Traversed-edges-per-second in billions (the paper's GTEP/s metric).
/// Uses the Graph500 convention the paper describes: |E| / time.
pub fn gteps(edges: u64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        return f64::INFINITY;
    }
    edges as f64 / seconds / 1e9
}

/// Relative speedup utilization (§5 Speedup Analysis):
/// `speedup = t_min_nodes / t_max_nodes`, `ideal = max_nodes / min_nodes`,
/// `utilization = speedup / ideal`.
#[derive(Clone, Copy, Debug)]
pub struct ScalingUtilization {
    /// Measured speedup going from the minimal to the maximal node count.
    pub speedup: f64,
    /// Ideal (linear) speedup for the same node-count ratio.
    pub ideal: f64,
    /// `speedup / ideal`, the paper's headline "75% utilization" metric.
    pub utilization: f64,
}

/// Compute the paper's speedup/ideal/utilization triple.
pub fn scaling_utilization(
    t_at_min_nodes: f64,
    min_nodes: usize,
    t_at_max_nodes: f64,
    max_nodes: usize,
) -> ScalingUtilization {
    let speedup = t_at_min_nodes / t_at_max_nodes;
    let ideal = max_nodes as f64 / min_nodes as f64;
    ScalingUtilization {
        speedup,
        ideal,
        utilization: speedup / ideal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_simple() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        // sample stddev of 1..4 = sqrt(5/3)
        assert!((s.stddev - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        // 100 samples: one absurdly fast, one absurdly slow, 98 at 1.0.
        let mut xs = vec![1.0; 98];
        xs.push(0.0001);
        xs.push(1000.0);
        let tm = trimmed_mean(&xs, 1);
        assert!((tm - 1.0).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_paper_protocol() {
        // The paper: 100 roots, drop 25 fastest + 25 slowest.
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let tm = trimmed_mean(&xs, 25);
        // Remaining 25..=74, mean = 49.5
        assert!((tm - 49.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn trimmed_mean_overtrim_panics() {
        trimmed_mean(&[1.0, 2.0], 1);
    }

    #[test]
    fn gteps_matches_paper_scale() {
        // 8 B edges in ~26 ms ≈ 300 GTEP/s (the paper's headline).
        let g = gteps(8_000_000_000, 0.0266);
        assert!((g - 300.75).abs() < 1.0);
    }

    #[test]
    fn utilization_example_from_paper() {
        // GAP-kron: speedup 1.77 over ideal 2.0 → 88.4 %.
        let u = scaling_utilization(1.77, 8, 1.0, 16);
        assert!((u.speedup - 1.77).abs() < 1e-12);
        assert!((u.ideal - 2.0).abs() < 1e-12);
        assert!((u.utilization - 0.885).abs() < 1e-3);
    }
}
