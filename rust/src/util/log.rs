//! Tiny leveled stderr logger (the `log` crate's facade without the crate).
//!
//! Level is controlled by `BBFS_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Defaults to `info`.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or wrong-result conditions.
    Error = 0,
    /// Suspicious but non-fatal conditions.
    Warn = 1,
    /// Run-level progress (default).
    Info = 2,
    /// Per-iteration detail.
    Debug = 3,
    /// Per-message detail.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("BBFS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True when a message at `l` would be printed.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Core log call; use the macros instead.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

/// Log at error level.
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) };
}
/// Log at warn level.
#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}
/// Log at info level.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
/// Log at debug level.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}
/// Log at trace level.
#[macro_export]
macro_rules! trace {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_and_query_level() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
