//! Minimal JSON value model + writer (no `serde` in the offline set).
//!
//! Used to dump benchmark results and run metrics in a machine-readable
//! form alongside the human-readable tables.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true`/`false`
    Bool(bool),
    /// Any finite number (rendered with up to 17 significant digits).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Construct an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Construct a string value.
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Construct a number value.
    pub fn n(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    /// Construct a u64 number value (lossless below 2^53).
    pub fn u(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// Serialize to a compact string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 9.0e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::n(3.0).render(), "3");
        assert_eq!(Json::n(3.5).render(), "3.5");
        assert_eq!(Json::u(123456789).render(), "123456789");
        assert_eq!(Json::s("hi").render(), "\"hi\"");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::s("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::s("\u{1}").render(), "\"\\u0001\"");
    }

    #[test]
    fn nested_structure_sorted_keys() {
        let v = Json::obj(vec![
            ("zeta", Json::n(1.0)),
            ("alpha", Json::Arr(vec![Json::n(1.0), Json::s("x")])),
        ]);
        assert_eq!(v.render(), "{\"alpha\":[1,\"x\"],\"zeta\":1}");
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::n(f64::INFINITY).render(), "null");
        assert_eq!(Json::n(f64::NAN).render(), "null");
    }
}
