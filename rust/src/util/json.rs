//! Minimal JSON value model + writer (no `serde` in the offline set).
//!
//! Used to dump benchmark results and run metrics in a machine-readable
//! form alongside the human-readable tables.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true`/`false`
    Bool(bool),
    /// Any finite number (rendered with up to 17 significant digits).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Construct an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Construct a string value.
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Construct a number value.
    pub fn n(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    /// Construct a u64 number value (lossless below 2^53).
    pub fn u(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// Serialize to a compact string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 9.0e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (recursive descent over the full grammar;
    /// `\uXXXX` escapes decode basic-plane scalars, surrogate pairs are
    /// rejected). Added for the committed-artifact checkers
    /// (`bench-protocol --check`), which must *read* the JSON this module
    /// writes.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer value, if this is a number holding one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && (0.0..9.0e15).contains(x) => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Byte-cursor recursive-descent parser behind [`Json::parse`].
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    map.insert(k, self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err("unterminated string".to_string());
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".to_string());
                    };
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("\\u{hex} is not a scalar value"))?,
                            );
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                _ => {
                    // Re-consume from the byte cursor as UTF-8: step back
                    // and take the full character.
                    self.pos -= 1;
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::n(3.0).render(), "3");
        assert_eq!(Json::n(3.5).render(), "3.5");
        assert_eq!(Json::u(123456789).render(), "123456789");
        assert_eq!(Json::s("hi").render(), "\"hi\"");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::s("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::s("\u{1}").render(), "\"\\u0001\"");
    }

    #[test]
    fn nested_structure_sorted_keys() {
        let v = Json::obj(vec![
            ("zeta", Json::n(1.0)),
            ("alpha", Json::Arr(vec![Json::n(1.0), Json::s("x")])),
        ]);
        assert_eq!(v.render(), "{\"alpha\":[1,\"x\"],\"zeta\":1}");
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::n(f64::INFINITY).render(), "null");
        assert_eq!(Json::n(f64::NAN).render(), "null");
    }

    #[test]
    fn parse_roundtrips_rendered_output() {
        let v = Json::obj(vec![
            ("ints", Json::Arr(vec![Json::u(0), Json::u(123456789)])),
            ("float", Json::n(3.0625)),
            ("neg", Json::n(-2.5e-3)),
            ("s", Json::s("a\"b\\c\nd")),
            ("flag", Json::Bool(false)),
            ("nothing", Json::Null),
            ("nested", Json::obj(vec![("k", Json::Arr(vec![]))])),
        ]);
        let parsed = Json::parse(&v.render()).unwrap();
        assert_eq!(parsed, v);
        // And the roundtrip is render-stable.
        assert_eq!(parsed.render(), v.render());
    }

    #[test]
    fn parse_accepts_whitespace_and_unicode() {
        let v = Json::parse(" {\n \"a\" : [ 1 , 2.5 ] , \"b\" : \"\\u0041π\" }\n")
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap().as_str(), Some("Aπ"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_u64(), Some(1));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"\\q\"", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} parsed");
        }
    }
}
