//! A miniature property-testing harness (no `proptest` in the offline set).
//!
//! Provides seeded random-input generation, a configurable number of cases,
//! and greedy shrinking for integers and vectors. Used throughout the test
//! suite for the coordinator invariants (routing, schedule coverage,
//! batching bounds, distance-array agreement).
//!
//! ```no_run
//! use butterfly_bfs::util::propcheck::{Config, forall};
//! forall(Config::default(), "sum is commutative", |rng| {
//!     let a = rng.next_below(1000) as i64;
//!     let b = rng.next_below(1000) as i64;
//!     (a + b == b + a, format!("a={a} b={b}"))
//! });
//! ```

use crate::util::prng::Xoshiro256StarStar;

/// Property-test configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to try.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xB0FF_EAF1 }
    }
}

impl Config {
    /// Config with a custom case count.
    pub fn cases(n: usize) -> Self {
        Self { cases: n, ..Self::default() }
    }
}

/// Run `prop` on `cfg.cases` seeded RNGs; the property returns
/// `(holds, description_of_inputs)`. Panics (failing the test) on the first
/// violated case, reporting the seed so it can be replayed.
pub fn forall<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Xoshiro256StarStar) -> (bool, String),
{
    for i in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(i as u64);
        let mut rng = Xoshiro256StarStar::seed_from_u64(case_seed);
        let (ok, desc) = prop(&mut rng);
        assert!(
            ok,
            "property {name:?} violated at case {i} (seed {case_seed:#x}): {desc}"
        );
    }
}

/// Greedy shrink of a failing integer input: repeatedly halve toward
/// `lo` while the predicate still fails; returns the smallest failing value
/// found.
pub fn shrink_int<F>(mut value: u64, lo: u64, mut fails: F) -> u64
where
    F: FnMut(u64) -> bool,
{
    debug_assert!(fails(value), "shrink_int: initial value does not fail");
    loop {
        if value == lo {
            return value;
        }
        let candidate = lo + (value - lo) / 2;
        if candidate != value && fails(candidate) {
            value = candidate;
        } else if value > lo && fails(value - 1) {
            value -= 1;
        } else {
            return value;
        }
    }
}

/// Greedy shrink of a failing vector input: try removing chunks (halves,
/// quarters, … single elements) while the predicate still fails.
pub fn shrink_vec<T: Clone, F>(mut v: Vec<T>, mut fails: F) -> Vec<T>
where
    F: FnMut(&[T]) -> bool,
{
    debug_assert!(fails(&v), "shrink_vec: initial vector does not fail");
    let mut chunk = (v.len() / 2).max(1);
    while chunk >= 1 {
        let mut i = 0;
        let mut removed_any = false;
        while i + chunk <= v.len() {
            let mut candidate = Vec::with_capacity(v.len() - chunk);
            candidate.extend_from_slice(&v[..i]);
            candidate.extend_from_slice(&v[i + chunk..]);
            if fails(&candidate) {
                v = candidate;
                removed_any = true;
                // keep i (next chunk shifted into place)
            } else {
                i += chunk;
            }
        }
        if chunk == 1 && !removed_any {
            break;
        }
        if !removed_any {
            chunk /= 2;
        }
    }
    v
}

/// Convenience generators used by many properties.
pub mod gen {
    use crate::util::prng::Xoshiro256StarStar;

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(rng: &mut Xoshiro256StarStar, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + rng.next_usize(hi - lo + 1)
    }

    /// A random vector of `len` values below `bound`.
    pub fn vec_below(rng: &mut Xoshiro256StarStar, len: usize, bound: u64) -> Vec<u64> {
        (0..len).map(|_| rng.next_below(bound)).collect()
    }

    /// A random undirected edge list over `n` vertices with `m` edges
    /// (possibly with duplicates/self-loops — exercise the ETL!).
    pub fn edge_list(
        rng: &mut Xoshiro256StarStar,
        n: usize,
        m: usize,
    ) -> Vec<(u32, u32)> {
        (0..m)
            .map(|_| (rng.next_usize(n) as u32, rng.next_usize(n) as u32))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(Config::cases(32), "xor involution", |rng| {
            let x = rng.next_u64();
            let k = rng.next_u64();
            ((x ^ k) ^ k == x, format!("x={x} k={k}"))
        });
    }

    #[test]
    #[should_panic(expected = "violated")]
    fn forall_reports_failures() {
        forall(Config::cases(64), "always false eventually", |rng| {
            let x = rng.next_below(4);
            (x != 0, format!("x={x}"))
        });
    }

    #[test]
    fn shrink_int_finds_boundary() {
        // Fails iff >= 17; shrink from 1000 should land exactly on 17.
        let s = shrink_int(1000, 0, |v| v >= 17);
        assert_eq!(s, 17);
    }

    #[test]
    fn shrink_vec_minimizes() {
        // Fails iff the vector contains a 7; minimal failing vector is [7].
        let v = vec![1u64, 2, 7, 3, 7, 9];
        let s = shrink_vec(v, |v| v.contains(&7));
        assert_eq!(s, vec![7]);
    }

    #[test]
    fn gen_edge_list_in_range() {
        let mut rng = crate::util::prng::Xoshiro256StarStar::seed_from_u64(4);
        let es = gen::edge_list(&mut rng, 50, 200);
        assert_eq!(es.len(), 200);
        assert!(es.iter().all(|&(u, v)| (u as usize) < 50 && (v as usize) < 50));
    }
}
