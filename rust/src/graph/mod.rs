//! Graph substrate: CSR storage, ETL (the paper's §4 input pipeline),
//! synthetic generators for the Table-1 analog suite, file I/O, and
//! property analysis.

pub mod builder;
pub mod csr;
pub mod gen;
pub mod io;
pub mod props;
pub mod store;

pub use builder::{EtlStats, GraphBuilder};
pub use csr::{Csr, CsrSlab, VertexId};
