//! Graph I/O: edge-list text, Matrix Market (SuiteSparse's format), and a
//! fast binary snapshot format (`.bbfs`).

use super::builder::{EtlStats, GraphBuilder};
use super::csr::{Csr, VertexId};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// I/O errors.
#[derive(Debug)]
pub enum IoError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// Malformed input file.
    Parse {
        /// 1-based line number.
        line: usize,
        /// Description.
        msg: String,
    },
    /// Bad magic / version in binary snapshot.
    BadSnapshot(String),
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "io: {e}"),
            IoError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            IoError::BadSnapshot(msg) => write!(f, "bad .bbfs snapshot: {msg}"),
        }
    }
}

impl std::error::Error for IoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            IoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        IoError::Io(e)
    }
}

fn parse_err(line: usize, msg: impl Into<String>) -> IoError {
    IoError::Parse { line, msg: msg.into() }
}

/// Read a whitespace-separated edge list (`u v` per line, `#`/`%` comments).
/// Vertex count is `max id + 1` unless `n_hint` is larger.
pub fn read_edge_list(path: &Path, n_hint: Option<usize>) -> Result<(Csr, EtlStats), IoError> {
    let f = std::fs::File::open(path)?;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut max_id: u64 = 0;
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u64 = it
            .next()
            .ok_or_else(|| parse_err(i + 1, "missing source"))?
            .parse()
            .map_err(|e| parse_err(i + 1, format!("bad source: {e}")))?;
        let v: u64 = it
            .next()
            .ok_or_else(|| parse_err(i + 1, "missing target"))?
            .parse()
            .map_err(|e| parse_err(i + 1, format!("bad target: {e}")))?;
        if u >= u32::MAX as u64 || v >= u32::MAX as u64 {
            return Err(parse_err(i + 1, "vertex id exceeds u32"));
        }
        max_id = max_id.max(u).max(v);
        edges.push((u as VertexId, v as VertexId));
    }
    let n = n_hint.unwrap_or(0).max(if edges.is_empty() { 0 } else { max_id as usize + 1 });
    let mut b = GraphBuilder::new(n);
    b.add_edges(&edges);
    Ok(b.build_undirected())
}

/// Write a CSR as an edge list (each arc once; the reader re-symmetrizes).
pub fn write_edge_list(g: &Csr, path: &Path) -> Result<(), IoError> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# {} vertices, {} arcs", g.num_vertices(), g.num_edges())?;
    for u in 0..g.num_vertices() as VertexId {
        for &v in g.neighbors(u) {
            if u <= v {
                writeln!(w, "{u} {v}")?;
            }
        }
    }
    Ok(())
}

/// Read a Matrix Market coordinate-pattern file (SuiteSparse's interchange
/// format; 1-based indices). Only `matrix coordinate` headers are accepted;
/// values (if present) are ignored, so `pattern`/`real`/`integer` all work.
pub fn read_matrix_market(path: &Path) -> Result<(Csr, EtlStats), IoError> {
    let f = std::fs::File::open(path)?;
    let mut lines = BufReader::new(f).lines().enumerate();
    // Header
    let (i0, header) = lines
        .next()
        .ok_or_else(|| parse_err(1, "empty file"))
        .and_then(|(i, l)| Ok((i, l?)))?;
    if !header.starts_with("%%MatrixMarket matrix coordinate") {
        return Err(parse_err(i0 + 1, "not a MatrixMarket coordinate matrix"));
    }
    // Size line (after comments)
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    for (i, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        if dims.is_none() {
            let r: usize = it
                .next()
                .ok_or_else(|| parse_err(i + 1, "missing rows"))?
                .parse()
                .map_err(|e| parse_err(i + 1, format!("bad rows: {e}")))?;
            let c: usize = it
                .next()
                .ok_or_else(|| parse_err(i + 1, "missing cols"))?
                .parse()
                .map_err(|e| parse_err(i + 1, format!("bad cols: {e}")))?;
            let nnz: usize = it
                .next()
                .ok_or_else(|| parse_err(i + 1, "missing nnz"))?
                .parse()
                .map_err(|e| parse_err(i + 1, format!("bad nnz: {e}")))?;
            dims = Some((r, c, nnz));
            edges.reserve(nnz);
            continue;
        }
        let u: usize = it
            .next()
            .ok_or_else(|| parse_err(i + 1, "missing row"))?
            .parse()
            .map_err(|e| parse_err(i + 1, format!("bad row: {e}")))?;
        let v: usize = it
            .next()
            .ok_or_else(|| parse_err(i + 1, "missing col"))?
            .parse()
            .map_err(|e| parse_err(i + 1, format!("bad col: {e}")))?;
        if u == 0 || v == 0 {
            return Err(parse_err(i + 1, "MatrixMarket indices are 1-based"));
        }
        edges.push(((u - 1) as VertexId, (v - 1) as VertexId));
    }
    let (r, c, _) = dims.ok_or_else(|| parse_err(0, "missing size line"))?;
    let n = r.max(c);
    let mut b = GraphBuilder::new(n);
    b.add_edges(&edges);
    Ok(b.build_undirected())
}

const BBFS_MAGIC: &[u8; 8] = b"BBFSCSR1";

/// Write the binary `.bbfs` snapshot (magic, n, m, offsets, edges; LE).
///
/// Crash-consistent: the snapshot is staged in full and published with
/// [`crate::util::fsio::atomic_write`], so a writer killed mid-way never
/// leaves a torn file that [`read_binary`] would have to reject — the
/// destination is either the old complete snapshot or the new one.
pub fn write_binary(g: &Csr, path: &Path) -> Result<(), IoError> {
    let mut buf = Vec::with_capacity(8 + 16 + g.offsets().len() * 8 + g.edges().len() * 4);
    buf.extend_from_slice(BBFS_MAGIC);
    buf.extend_from_slice(&(g.num_vertices() as u64).to_le_bytes());
    buf.extend_from_slice(&g.num_edges().to_le_bytes());
    for &o in g.offsets() {
        buf.extend_from_slice(&o.to_le_bytes());
    }
    for &e in g.edges() {
        buf.extend_from_slice(&e.to_le_bytes());
    }
    crate::util::fsio::atomic_write(path, &buf)?;
    Ok(())
}

/// Read a `.bbfs` snapshot written by [`write_binary`].
///
/// The header-declared `n`/`m` are validated against the actual file
/// length **before** any allocation, and offsets/edge ids are fully
/// bound-checked — a truncated, oversized, or hostile snapshot returns
/// [`IoError::BadSnapshot`] instead of aborting on OOM or panicking
/// later inside the traversal.
pub fn read_binary(path: &Path) -> Result<Csr, IoError> {
    let f = std::fs::File::open(path)?;
    let actual_len = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BBFS_MAGIC {
        return Err(IoError::BadSnapshot("wrong magic".into()));
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8);
    r.read_exact(&mut b8)?;
    let m = u64::from_le_bytes(b8);
    if n > u32::MAX as u64 {
        return Err(IoError::BadSnapshot(format!(
            "declared {n} vertices exceed the u32 id space"
        )));
    }
    // Exact length check in u128 so a header like n = u64::MAX can't
    // overflow the arithmetic, let alone reach an allocator.
    let expected_len = 24u128 + 8 * (n as u128 + 1) + 4 * m as u128;
    if expected_len != u128::from(actual_len) {
        return Err(IoError::BadSnapshot(format!(
            "declared sizes need {expected_len} bytes but file has {actual_len}"
        )));
    }
    let n = n as usize;
    let m = m as usize;
    let mut offsets = Vec::with_capacity(n + 1);
    let mut prev = 0u64;
    for i in 0..=n {
        r.read_exact(&mut b8)?;
        let o = u64::from_le_bytes(b8);
        if i == 0 && o != 0 {
            return Err(IoError::BadSnapshot("offsets must start at 0".into()));
        }
        if o < prev {
            return Err(IoError::BadSnapshot(format!(
                "non-monotonic offset at vertex {i}: {o} < {prev}"
            )));
        }
        prev = o;
        offsets.push(o);
    }
    if prev != m as u64 {
        return Err(IoError::BadSnapshot(format!(
            "offsets end at {prev}, expected edge count {m}"
        )));
    }
    let mut edges = Vec::with_capacity(m);
    let mut b4 = [0u8; 4];
    for _ in 0..m {
        r.read_exact(&mut b4)?;
        let e = u32::from_le_bytes(b4);
        if e as u64 >= n as u64 {
            return Err(IoError::BadSnapshot(format!("edge target {e} out of range (n={n})")));
        }
        edges.push(e);
    }
    // All invariants `Csr::from_parts` asserts are now proven, so this
    // constructor cannot panic on hostile input.
    Ok(Csr::from_parts(offsets, edges))
}

/// Which `.bbfs` container generation a file holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotKind {
    /// Raw-CSR v1 snapshot ([`read_binary`]).
    V1,
    /// Compressed v2 container ([`crate::graph::store::GraphStore`]).
    V2,
    /// Neither magic — not a `.bbfs` file.
    Unknown,
}

/// Sniff the snapshot generation from the file magic (first 8 bytes),
/// so `.bbfs` paths dispatch to the right reader.
pub fn snapshot_kind(path: &Path) -> Result<SnapshotKind, IoError> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    if f.read_exact(&mut magic).is_err() {
        return Ok(SnapshotKind::Unknown);
    }
    Ok(if &magic == BBFS_MAGIC {
        SnapshotKind::V1
    } else if &magic == crate::graph::store::V2_MAGIC {
        SnapshotKind::V2
    } else {
        SnapshotKind::Unknown
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::kronecker::{kronecker, KroneckerParams};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("bbfs-test-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let (g, _) = kronecker(KroneckerParams::graph500(8, 4), 11);
        let p = tmp("el.txt");
        write_edge_list(&g, &p).unwrap();
        let (g2, _) = read_edge_list(&p, Some(g.num_vertices())).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_comments_and_blanks() {
        let p = tmp("el2.txt");
        std::fs::write(&p, "# comment\n\n0 1\n% another\n1 2\n").unwrap();
        let (g, _) = read_edge_list(&p, None).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 4);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_bad_token_errors() {
        let p = tmp("el3.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(matches!(
            read_edge_list(&p, None),
            Err(IoError::Parse { line: 1, .. })
        ));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn matrix_market_basic() {
        let p = tmp("mm.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern symmetric\n% c\n3 3 2\n1 2\n2 3\n",
        )
        .unwrap();
        let (g, _) = read_matrix_market(&p).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(g.has_edge(1, 2));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn matrix_market_rejects_non_coordinate() {
        let p = tmp("mm2.mtx");
        std::fs::write(&p, "%%MatrixMarket matrix array real general\n2 2\n1\n2\n3\n4\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let (g, _) = kronecker(KroneckerParams::graph500(9, 8), 13);
        let p = tmp("g.bbfs");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("bad.bbfs");
        std::fs::write(&p, b"NOTMAGIC________").unwrap();
        assert!(matches!(read_binary(&p), Err(IoError::BadSnapshot(_))));
        std::fs::remove_file(&p).ok();
    }

    /// A valid snapshot image for the corpus tests below.
    fn valid_v1_image() -> Vec<u8> {
        let (g, _) = kronecker(KroneckerParams::graph500(6, 4), 17);
        let p = tmp("corpus-base.bbfs");
        write_binary(&g, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        bytes
    }

    fn read_image(name: &str, bytes: &[u8]) -> Result<Csr, IoError> {
        let p = tmp(name);
        std::fs::write(&p, bytes).unwrap();
        let out = read_binary(&p);
        std::fs::remove_file(&p).ok();
        out
    }

    /// Corrupt-snapshot corpus: every hostile mutation must come back as
    /// a typed `BadSnapshot` — no panic, no attempted huge allocation.
    #[test]
    fn binary_corrupt_corpus_returns_typed_errors() {
        let base = valid_v1_image();
        let n = u64::from_le_bytes(base[8..16].try_into().unwrap()) as usize;
        let offsets_at = 24;
        let edges_at = offsets_at + 8 * (n + 1);

        // Truncation at every section boundary (and mid-section).
        for (name, cut) in [
            ("empty", 0usize),
            ("mid-magic", 4),
            ("after-magic", 8),
            ("mid-header", 20),
            ("after-header", 24),
            ("mid-offsets", offsets_at + 12),
            ("after-offsets", edges_at),
            ("mid-edges", base.len() - 2),
        ] {
            let img = &base[..cut];
            assert!(
                read_image("corpus-trunc.bbfs", img).is_err(),
                "truncation at {name} ({cut} bytes) must be rejected"
            );
        }

        // Oversized header: n = u64::MAX must fail the length check
        // before any allocation (the arithmetic is done in u128).
        let mut img = base.clone();
        img[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            read_image("corpus-huge-n.bbfs", &img),
            Err(IoError::BadSnapshot(_))
        ));

        // Declared m inflated without matching bytes.
        let mut img = base.clone();
        let m = u64::from_le_bytes(base[16..24].try_into().unwrap());
        img[16..24].copy_from_slice(&(m + 1).to_le_bytes());
        assert!(matches!(
            read_image("corpus-bad-m.bbfs", &img),
            Err(IoError::BadSnapshot(_))
        ));

        // Non-monotonic offsets.
        let mut img = base.clone();
        img[offsets_at + 8..offsets_at + 16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            read_image("corpus-nonmono.bbfs", &img),
            Err(IoError::BadSnapshot(_))
        ));

        // First offset not zero.
        let mut img = base.clone();
        img[offsets_at..offsets_at + 8].copy_from_slice(&1u64.to_le_bytes());
        assert!(matches!(
            read_image("corpus-off0.bbfs", &img),
            Err(IoError::BadSnapshot(_))
        ));

        // Edge target out of range.
        let mut img = base.clone();
        img[edges_at..edges_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_image("corpus-bad-edge.bbfs", &img),
            Err(IoError::BadSnapshot(_))
        ));

        // Trailing garbage (length mismatch in the other direction).
        let mut img = base.clone();
        img.extend_from_slice(&[0u8; 7]);
        assert!(matches!(
            read_image("corpus-trailing.bbfs", &img),
            Err(IoError::BadSnapshot(_))
        ));

        // And the untouched base still reads fine.
        assert!(read_image("corpus-ok.bbfs", &base).is_ok());
    }

    #[test]
    fn snapshot_kind_sniffs_generations() {
        let (g, _) = kronecker(KroneckerParams::graph500(5, 4), 3);
        let p = tmp("kind.bbfs");
        write_binary(&g, &p).unwrap();
        assert_eq!(snapshot_kind(&p).unwrap(), SnapshotKind::V1);
        std::fs::write(&p, crate::graph::store::V2_MAGIC).unwrap();
        assert_eq!(snapshot_kind(&p).unwrap(), SnapshotKind::V2);
        std::fs::write(&p, b"short").unwrap();
        assert_eq!(snapshot_kind(&p).unwrap(), SnapshotKind::Unknown);
        std::fs::remove_file(&p).ok();
    }
}
