//! Compressed Sparse Row graph storage.
//!
//! Vertex ids are `u32` (the paper's graphs stay under 2³² vertices; 32-bit
//! ids halve memory traffic on the traversal hot path — see DESIGN.md §8).
//! Offsets are `u64` so edge counts can exceed 4 B.

/// A vertex identifier.
pub type VertexId = u32;

/// An immutable CSR graph (directed adjacency; undirected graphs store both
/// arcs, as the paper's ETL does after symmetrization).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// `offsets[v]..offsets[v+1]` indexes `edges` with `v`'s out-neighbors.
    offsets: Vec<u64>,
    /// Flattened adjacency arrays, sorted within each vertex.
    edges: Vec<VertexId>,
}

impl Csr {
    /// Build from raw parts. `offsets` must be monotone, start at 0, have
    /// length `n+1`, and end at `edges.len()`.
    pub fn from_parts(offsets: Vec<u64>, edges: Vec<VertexId>) -> Self {
        assert!(!offsets.is_empty(), "offsets must have length n+1 >= 1");
        assert_eq!(offsets[0], 0, "offsets[0] must be 0");
        assert_eq!(
            *offsets.last().unwrap(),
            edges.len() as u64,
            "offsets must end at edges.len()"
        );
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]), "offsets monotone");
        Self { offsets, edges }
    }

    /// Build a CSR from an (already clean) edge list: counting sort by
    /// source. Does **not** dedup or symmetrize — that is
    /// [`crate::graph::builder::GraphBuilder`]'s job.
    pub fn from_edges(n: usize, edge_list: &[(VertexId, VertexId)]) -> Self {
        let mut counts = vec![0u64; n + 1];
        for &(u, _) in edge_list {
            assert!((u as usize) < n, "source {u} out of range (n={n})");
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut edges = vec![0 as VertexId; edge_list.len()];
        for &(u, v) in edge_list {
            assert!((v as usize) < n, "target {v} out of range (n={n})");
            let slot = cursor[u as usize];
            edges[slot as usize] = v;
            cursor[u as usize] += 1;
        }
        // Sort each adjacency run for deterministic traversal order and
        // binary-searchable neighbor lookups.
        for v in 0..n {
            let (s, e) = (offsets[v] as usize, offsets[v + 1] as usize);
            edges[s..e].sort_unstable();
        }
        Self { offsets, edges }
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of stored arcs (2× the undirected edge count after
    /// symmetrization).
    #[inline]
    pub fn num_edges(&self) -> u64 {
        *self.offsets.last().unwrap()
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// Out-neighbors of `v` (sorted).
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.edges[s..e]
    }

    /// True when arc `(u, v)` is present (binary search).
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Raw offsets (length `n+1`).
    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw flattened edge array.
    #[inline]
    pub fn edges(&self) -> &[VertexId] {
        &self.edges
    }

    /// Maximum out-degree.
    pub fn max_degree(&self) -> u32 {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Extract the subgraph rows for vertices `[lo, hi)` — the adjacency
    /// "slab" a compute node owns under 1D partitioning. Column ids stay
    /// global.
    pub fn row_slice(&self, lo: VertexId, hi: VertexId) -> CsrSlab {
        assert!(lo <= hi && (hi as usize) <= self.num_vertices());
        let s = self.offsets[lo as usize];
        let e = self.offsets[hi as usize];
        let offsets: Vec<u64> = self.offsets[lo as usize..=hi as usize]
            .iter()
            .map(|o| o - s)
            .collect();
        CsrSlab {
            first_vertex: lo,
            offsets,
            edges: self.edges[s as usize..e as usize].to_vec(),
        }
    }

    /// Memory footprint in bytes (offsets + edges).
    pub fn bytes(&self) -> u64 {
        (self.offsets.len() * std::mem::size_of::<u64>()
            + self.edges.len() * std::mem::size_of::<VertexId>()) as u64
    }
}

/// A contiguous row-range of a CSR: the per-compute-node partition slab.
/// Rows are local (`0..num_rows`), columns remain global vertex ids —
/// exactly the paper's 1D layout where any node can *discover* any vertex
/// but owns only its own row range.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrSlab {
    /// Global id of local row 0.
    pub first_vertex: VertexId,
    /// Local offsets, length `num_rows + 1`.
    pub offsets: Vec<u64>,
    /// Flattened adjacency (global column ids).
    pub edges: Vec<VertexId>,
}

impl CsrSlab {
    /// Number of owned rows.
    #[inline]
    pub fn num_rows(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of stored arcs.
    #[inline]
    pub fn num_edges(&self) -> u64 {
        *self.offsets.last().unwrap()
    }

    /// One past the last owned global vertex id.
    #[inline]
    pub fn end_vertex(&self) -> VertexId {
        self.first_vertex + self.num_rows() as VertexId
    }

    /// True when this slab owns global vertex `v`.
    #[inline]
    pub fn owns(&self, v: VertexId) -> bool {
        v >= self.first_vertex && v < self.end_vertex()
    }

    /// Neighbors of *global* vertex `v` (must be owned).
    #[inline]
    pub fn neighbors_global(&self, v: VertexId) -> &[VertexId] {
        debug_assert!(self.owns(v));
        let r = (v - self.first_vertex) as usize;
        let s = self.offsets[r] as usize;
        let e = self.offsets[r + 1] as usize;
        &self.edges[s..e]
    }

    /// Out-degree of *global* vertex `v` (must be owned).
    #[inline]
    pub fn degree_global(&self, v: VertexId) -> u32 {
        debug_assert!(self.owns(v));
        let r = (v - self.first_vertex) as usize;
        (self.offsets[r + 1] - self.offsets[r]) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0-1, 0-2, 1-3, 2-3 undirected (both arcs stored)
        Csr::from_edges(
            4,
            &[(0, 1), (1, 0), (0, 2), (2, 0), (1, 3), (3, 1), (2, 3), (3, 2)],
        )
    }

    #[test]
    fn from_edges_basic() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 8);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[1, 2]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn neighbors_sorted_even_if_input_unsorted() {
        let g = Csr::from_edges(3, &[(0, 2), (0, 1)]);
        assert_eq!(g.neighbors(0), &[1, 2]);
    }

    #[test]
    fn has_edge() {
        let g = diamond();
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(0, &[]);
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn isolated_vertices() {
        let g = Csr::from_edges(5, &[(0, 1)]);
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.neighbors(3), &[] as &[VertexId]);
    }

    #[test]
    fn row_slice_slab() {
        let g = diamond();
        let slab = g.row_slice(1, 3); // rows 1 and 2
        assert_eq!(slab.num_rows(), 2);
        assert_eq!(slab.first_vertex, 1);
        assert!(slab.owns(1) && slab.owns(2));
        assert!(!slab.owns(0) && !slab.owns(3));
        assert_eq!(slab.neighbors_global(1), &[0, 3]);
        assert_eq!(slab.neighbors_global(2), &[0, 3]);
        assert_eq!(slab.degree_global(2), 2);
        assert_eq!(slab.num_edges(), 4);
    }

    #[test]
    fn row_slice_full_equals_graph() {
        let g = diamond();
        let slab = g.row_slice(0, 4);
        assert_eq!(slab.num_edges(), g.num_edges());
        for v in 0..4u32 {
            assert_eq!(slab.neighbors_global(v), g.neighbors(v));
        }
    }

    #[test]
    #[should_panic]
    fn from_parts_bad_offsets() {
        Csr::from_parts(vec![0, 5], vec![1, 2]);
    }
}
