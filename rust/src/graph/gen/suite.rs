//! The Table-1 analog graph suite (DESIGN.md §7).
//!
//! Each paper input is mapped to a synthetic analog from the same
//! generator family / degree class / diameter class, at a scale that runs
//! on one core in minutes. `GraphSpec::generate` is the single entry point
//! used by benches and examples so every experiment sees the same suite.

use super::kronecker::{kronecker, KroneckerParams};
use super::urand::uniform_random;
use super::weblike::{weblike, WeblikeParams};
use crate::graph::csr::Csr;

/// How a suite graph is generated.
#[derive(Clone, Copy, Debug)]
pub enum Family {
    /// Graph500 Kronecker/R-MAT.
    Kronecker {
        /// log2 of vertex count.
        scale: u32,
        /// arcs per vertex.
        edge_factor: u32,
    },
    /// Uniform random (Erdős–Rényi-like).
    Urand {
        /// log2 of vertex count.
        scale: u32,
        /// arcs per vertex.
        edge_factor: u32,
    },
    /// Preferential-attachment web core with deep strands and an optional
    /// path tail (diameter control without moving the mass).
    Weblike {
        /// log2 of vertex count.
        scale: u32,
        /// arcs per vertex.
        edge_factor: u32,
        /// appended path-tail length.
        tail: usize,
        /// fraction of vertices in deep strands (per-mille to stay Copy).
        strand_permille: u32,
        /// strand length.
        strand_len: usize,
    },
}

/// A named workload in the suite.
#[derive(Clone, Copy, Debug)]
pub struct GraphSpec {
    /// Analog name (the paper graph it stands in for).
    pub name: &'static str,
    /// The paper's original graph this substitutes.
    pub paper_graph: &'static str,
    /// Generator family + parameters.
    pub family: Family,
    /// RNG seed (fixed so every run/bench sees identical graphs).
    pub seed: u64,
    /// Diameter class of the original (for reporting).
    pub paper_diameter: u32,
}

impl GraphSpec {
    /// Generate the graph (symmetrized, deduplicated).
    pub fn generate(&self) -> Csr {
        self.generate_scaled(0)
    }

    /// Generate with `scale_delta` added to the scale exponent (used by the
    /// quick CI profile vs the full bench profile).
    pub fn generate_scaled(&self, scale_delta: i32) -> Csr {
        let adj = |s: u32| ((s as i32 + scale_delta).max(4)) as u32;
        match self.family {
            Family::Kronecker { scale, edge_factor } => {
                kronecker(KroneckerParams::graph500(adj(scale), edge_factor), self.seed).0
            }
            Family::Urand { scale, edge_factor } => {
                uniform_random(1usize << adj(scale), edge_factor, self.seed).0
            }
            Family::Weblike { scale, edge_factor, tail, strand_permille, strand_len } => {
                weblike(
                    WeblikeParams {
                        n: 1usize << adj(scale),
                        edge_factor,
                        copy_prob: 0.25,
                        tail_len: tail,
                        window: 0,
                        strand_frac: strand_permille as f64 / 1000.0,
                        strand_len,
                    },
                    self.seed,
                )
                .0
            }
        }
    }
}

/// The nine Table-1 rows, in the paper's order (smallest to largest edge
/// count, matching Fig. 3's layout).
pub fn table1_suite() -> Vec<GraphSpec> {
    vec![
        GraphSpec {
            name: "webbase-like",
            paper_graph: "Webbase-2001",
            family: Family::Weblike { scale: 20, edge_factor: 8, tail: 340, strand_permille: 150, strand_len: 30 },
            seed: 0xB0B0_0001,
            paper_diameter: 375,
        },
        GraphSpec {
            name: "it-like",
            paper_graph: "It-2004",
            family: Family::Weblike { scale: 20, edge_factor: 16, tail: 0, strand_permille: 200, strand_len: 11 },
            seed: 0xB0B0_0002,
            paper_diameter: 26,
        },
        GraphSpec {
            name: "uk-like",
            paper_graph: "Uk-2005",
            family: Family::Weblike { scale: 20, edge_factor: 24, tail: 0, strand_permille: 150, strand_len: 8 },
            seed: 0xB0B0_0003,
            paper_diameter: 21,
        },
        GraphSpec {
            name: "twitter-like",
            paper_graph: "GAP_twitter",
            family: Family::Kronecker { scale: 20, edge_factor: 24 },
            seed: 0xB0B0_0004,
            paper_diameter: 14,
        },
        GraphSpec {
            name: "friendster-like",
            paper_graph: "com-Friendster",
            family: Family::Kronecker { scale: 20, edge_factor: 28 },
            seed: 0xB0B0_0005,
            paper_diameter: 19,
        },
        GraphSpec {
            name: "web-like",
            paper_graph: "GAP_web",
            family: Family::Weblike { scale: 20, edge_factor: 38, tail: 0, strand_permille: 180, strand_len: 9 },
            seed: 0xB0B0_0006,
            paper_diameter: 23,
        },
        GraphSpec {
            name: "kron-like",
            paper_graph: "GAP_kron",
            family: Family::Kronecker { scale: 21, edge_factor: 16 },
            seed: 0xB0B0_0007,
            paper_diameter: 5,
        },
        GraphSpec {
            name: "urand-like",
            paper_graph: "GAP_urand",
            family: Family::Urand { scale: 21, edge_factor: 16 },
            seed: 0xB0B0_0008,
            paper_diameter: 7,
        },
        GraphSpec {
            name: "moliere-like",
            paper_graph: "MOLIERE_2016",
            family: Family::Urand { scale: 19, edge_factor: 50 },
            seed: 0xB0B0_0009,
            paper_diameter: 15,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_nine_rows_in_paper_order() {
        let s = table1_suite();
        assert_eq!(s.len(), 9);
        assert_eq!(s[0].paper_graph, "Webbase-2001");
        assert_eq!(s[8].paper_graph, "MOLIERE_2016");
    }

    #[test]
    fn all_specs_generate_at_reduced_scale() {
        for spec in table1_suite() {
            let g = spec.generate_scaled(-6); // tiny versions for CI
            assert!(g.num_vertices() > 0, "{}", spec.name);
            assert!(g.num_edges() > 0, "{}", spec.name);
        }
    }

    #[test]
    fn names_unique() {
        let s = table1_suite();
        let set: std::collections::HashSet<_> = s.iter().map(|x| x.name).collect();
        assert_eq!(set.len(), s.len());
    }
}
