//! Uniform-random graph generator (the `GAP_urand` analog).
//!
//! `edge_factor · n` arcs with both endpoints uniform — an Erdős–Rényi-like
//! G(n, m). Degree distribution is binomial (no hubs), diameter ~log n;
//! this is the input class where direction-optimizing BFS wins the most in
//! the paper's Table 1 (86× DO-over-TD for `GAP_urand`-like inputs).

use crate::graph::builder::{EtlStats, GraphBuilder};
use crate::graph::csr::{Csr, VertexId};
use crate::util::prng::Xoshiro256StarStar;

/// Generate a symmetrized uniform-random graph with `n` vertices and
/// `edge_factor * n` raw arcs.
pub fn uniform_random(n: usize, edge_factor: u32, seed: u64) -> (Csr, EtlStats) {
    assert!(n > 0 && (n as u64) < u32::MAX as u64);
    let m = n * edge_factor as usize;
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let mut b = GraphBuilder::new(n);
    b.reserve(m);
    for _ in 0..m {
        let u = rng.next_usize(n) as VertexId;
        let v = rng.next_usize(n) as VertexId;
        b.add_edge(u, v);
    }
    b.build_undirected()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        let (g, s) = uniform_random(1000, 8, 1);
        assert_eq!(g.num_vertices(), 1000);
        assert_eq!(s.raw_arcs, 8000);
        assert!(g.num_edges() <= 16_000);
        assert!(g.num_edges() > 10_000, "dedup should not remove most arcs");
    }

    #[test]
    fn deterministic() {
        assert_eq!(uniform_random(200, 4, 7).0, uniform_random(200, 4, 7).0);
    }

    #[test]
    fn flat_degree_distribution() {
        let (g, _) = uniform_random(4096, 16, 3);
        let mean = g.num_edges() as f64 / g.num_vertices() as f64;
        // Binomial tail: max degree within ~3x of mean for this size.
        assert!(
            (g.max_degree() as f64) < 3.0 * mean,
            "max {} vs mean {mean}",
            g.max_degree()
        );
    }
}
