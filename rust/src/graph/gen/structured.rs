//! Structured graphs with analytically known BFS distances — the test
//! oracles for every traversal engine in the repository.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{Csr, VertexId};

/// Path graph `0 - 1 - … - n−1`. Distance from 0 to v is exactly `v`;
/// diameter `n−1`. The worst case for BFS parallelism (one vertex per
/// level — the `Webbase-2001` pathology in its purest form).
pub fn path(n: usize) -> Csr {
    let mut b = GraphBuilder::new(n);
    for v in 1..n as VertexId {
        b.add_edge(v - 1, v);
    }
    b.build_undirected().0
}

/// Star graph: center 0 connected to `n−1` leaves. Two BFS levels; the
/// extreme load-imbalance case for LRB (one huge adjacency, many tiny).
pub fn star(n: usize) -> Csr {
    assert!(n >= 1);
    let mut b = GraphBuilder::new(n);
    for v in 1..n as VertexId {
        b.add_edge(0, v);
    }
    b.build_undirected().0
}

/// Complete graph K_n. One BFS level from any root.
pub fn complete(n: usize) -> Csr {
    let mut b = GraphBuilder::new(n);
    for u in 0..n as VertexId {
        for v in (u + 1)..n as VertexId {
            b.add_edge(u, v);
        }
    }
    b.build_undirected().0
}

/// `rows × cols` 2D grid; distance from corner (0,0) to (r,c) is `r+c`
/// (Manhattan). Mid-diameter structured input.
pub fn grid2d(rows: usize, cols: usize) -> Csr {
    let n = rows * cols;
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    let mut b = GraphBuilder::new(n);
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                b.add_edge(id(r, c), id(r, c + 1));
            }
            if r + 1 < rows {
                b.add_edge(id(r, c), id(r + 1, c));
            }
        }
    }
    b.build_undirected().0
}

/// Complete binary tree with `n` vertices (heap indexing: children of `v`
/// are `2v+1`, `2v+2`). Distance from root 0 to v is `floor(log2(v+1))`.
pub fn binary_tree(n: usize) -> Csr {
    let mut b = GraphBuilder::new(n);
    for v in 1..n {
        b.add_edge(((v - 1) / 2) as VertexId, v as VertexId);
    }
    b.build_undirected().0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;

    #[test]
    fn path_distances() {
        let g = path(50);
        let d = serial_bfs(&g, 0);
        for v in 0..50 {
            assert_eq!(d[v], v as u32);
        }
    }

    #[test]
    fn star_distances() {
        let g = star(100);
        let d = serial_bfs(&g, 0);
        assert_eq!(d[0], 0);
        assert!(d[1..].iter().all(|&x| x == 1));
        let d_leaf = serial_bfs(&g, 42);
        assert_eq!(d_leaf[0], 1);
        assert_eq!(d_leaf[42], 0);
        assert_eq!(d_leaf[43], 2);
    }

    #[test]
    fn complete_one_level() {
        let g = complete(20);
        let d = serial_bfs(&g, 3);
        assert_eq!(d[3], 0);
        assert!(d.iter().enumerate().all(|(v, &x)| v == 3 || x == 1));
    }

    #[test]
    fn grid_manhattan() {
        let (rows, cols) = (7, 9);
        let g = grid2d(rows, cols);
        let d = serial_bfs(&g, 0);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(d[r * cols + c], (r + c) as u32, "({r},{c})");
            }
        }
    }

    #[test]
    fn binary_tree_depth() {
        let g = binary_tree(127); // full tree of depth 6
        let d = serial_bfs(&g, 0);
        for v in 0..127usize {
            let depth = (usize::BITS - (v + 1).leading_zeros() - 1) as u32;
            assert_eq!(d[v], depth, "v={v}");
        }
    }
}
