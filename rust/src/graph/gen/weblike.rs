//! Web-crawl-like generator: power-law degrees with tunable diameter, plus
//! an optional long path "tail" reproducing `Webbase-2001`'s pathology
//! (§5: "a large tail of about one hundred vertices long — one at each
//! level", which starves parallelism and makes synchronization dominate).
//!
//! Mechanism: a preferential-attachment core (each new vertex attaches
//! `edge_factor` arcs to earlier vertices, biased by a copying model)
//! yields the power-law host-graph structure of It-2004/Uk-2005/GAP_web;
//! `tail_len > 0` appends a path of that length hanging off vertex 0.

use crate::graph::builder::{EtlStats, GraphBuilder};
use crate::graph::csr::{Csr, VertexId};
use crate::util::prng::Xoshiro256StarStar;

/// Parameters of the web-like generator.
#[derive(Clone, Copy, Debug)]
pub struct WeblikeParams {
    /// Vertices in the preferential-attachment core.
    pub n: usize,
    /// Arcs attached per new vertex.
    pub edge_factor: u32,
    /// Probability of copying a neighbor of the chosen target instead of
    /// the target itself (higher ⇒ heavier tail, more clustering).
    pub copy_prob: f64,
    /// Length of the appended path tail (0 = none). The tail adds
    /// `tail_len` vertices and `tail_len` edges and raises the diameter by
    /// `tail_len`.
    pub tail_len: usize,
    /// Attachment locality window: targets are drawn from the last
    /// `window` attachment endpoints instead of all of history
    /// (0 = global). Produces banded crawl-order structure.
    pub window: usize,
    /// Fraction of vertices allocated to *deep strands*: thin chains
    /// hanging off the core. Real host-level web graphs (It-2004,
    /// Uk-2005) are small-world cores (most mass within a few hops of
    /// hubs) whose 20–26 diameters come from sparse deep paths — not from
    /// the bulk being far away. Strands reproduce that: they add depth
    /// without mass, which is also what keeps direction-optimizing BFS
    /// only mildly better than top-down on these inputs (Table 1's
    /// 1.07–1.9× web rows).
    pub strand_frac: f64,
    /// Length of each strand (vertices per chain).
    pub strand_len: usize,
}

impl WeblikeParams {
    /// A plain global preferential-attachment core (no strands/tail).
    pub fn core(n: usize, edge_factor: u32) -> Self {
        Self {
            n,
            edge_factor,
            copy_prob: 0.25,
            tail_len: 0,
            window: 0,
            strand_frac: 0.0,
            strand_len: 0,
        }
    }
}

/// Generate a symmetrized web-like graph.
pub fn weblike(p: WeblikeParams, seed: u64) -> (Csr, EtlStats) {
    assert!(p.n >= 2);
    assert!((0.0..1.0).contains(&p.strand_frac));
    // Strand vertices are carved out of `n`; the core shrinks accordingly.
    let strand_total = (p.n as f64 * p.strand_frac) as usize;
    let n_core = (p.n - strand_total).max(2);
    let total = p.n + p.tail_len;
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let mut b = GraphBuilder::new(total);
    b.reserve(p.n * p.edge_factor as usize + p.tail_len);
    // Seed edge so early vertices have something to attach to.
    b.add_edge(0, 1);
    // Growing arc list for preferential attachment by arc-endpoint
    // sampling (classic Barabási–Albert trick: sampling a uniform endpoint
    // of an existing arc is degree-proportional sampling).
    let mut endpoints: Vec<VertexId> = vec![0, 1];
    for v in 2..n_core as VertexId {
        for _ in 0..p.edge_factor {
            // Locality window: degree-proportional sampling restricted to
            // the most recent attachments (crawl locality).
            let lo = if p.window > 0 && endpoints.len() > p.window {
                endpoints.len() - p.window
            } else {
                0
            };
            let mut t = endpoints[lo + rng.next_usize(endpoints.len() - lo)];
            if rng.next_bool(p.copy_prob) {
                // Copying model: jump to a uniform vertex in the same
                // locality window instead.
                let wlo = if p.window > 0 && (v as usize) > p.window {
                    v as usize - p.window
                } else {
                    0
                };
                t = (wlo + rng.next_usize(v as usize - wlo)) as VertexId;
            }
            b.add_edge(v, t);
            endpoints.push(v);
            endpoints.push(t);
        }
    }
    // Deep strands: thin chains rooted at uniform core vertices. Depth
    // without mass — the source of real web-graph diameters.
    if strand_total > 0 {
        let strand_len = p.strand_len.max(1);
        let mut next_id = n_core as VertexId;
        let end = (n_core + strand_total) as VertexId;
        while next_id < end {
            let mut prev = rng.next_usize(n_core) as VertexId; // root in core
            for _ in 0..strand_len {
                if next_id >= end {
                    break;
                }
                b.add_edge(prev, next_id);
                prev = next_id;
                next_id += 1;
            }
        }
    }
    // Appended path tail off vertex 0: 0 - n - n+1 - ... - n+tail_len-1.
    let mut prev = 0 as VertexId;
    for i in 0..p.tail_len {
        let t = (p.n + i) as VertexId;
        b.add_edge(prev, t);
        prev = t;
    }
    b.build_undirected()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::serial_bfs;

    fn core(n: usize, ef: u32) -> WeblikeParams {
        WeblikeParams { copy_prob: 0.2, ..WeblikeParams::core(n, ef) }
    }

    #[test]
    fn sizes() {
        let (g, _) = weblike(core(2000, 8), 1);
        assert_eq!(g.num_vertices(), 2000);
        assert!(g.num_edges() > 2000);
    }

    #[test]
    fn deterministic() {
        assert_eq!(weblike(core(500, 4), 9).0, weblike(core(500, 4), 9).0);
    }

    #[test]
    fn power_law_ish() {
        let (g, _) = weblike(core(8192, 8), 2);
        let mean = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(
            (g.max_degree() as f64) > 10.0 * mean,
            "expected hubs: max {} vs mean {mean}",
            g.max_degree()
        );
    }

    #[test]
    fn tail_raises_eccentricity() {
        let p = WeblikeParams { tail_len: 100, ..core(1000, 8) };
        let (g, _) = weblike(p, 3);
        assert_eq!(g.num_vertices(), 1100);
        // BFS from the tail end must reach depth >= 100.
        let d = serial_bfs(&g, (1099) as VertexId);
        let max_d = d.iter().filter(|&&x| x != u32::MAX).max().copied().unwrap();
        assert!(max_d >= 100, "max depth {max_d}");
    }

    #[test]
    fn connected_core() {
        // Preferential attachment always attaches to existing component:
        // the core is connected.
        let (g, _) = weblike(core(300, 4), 5);
        let d = serial_bfs(&g, 0);
        assert!(
            d.iter().take(300).all(|&x| x != u32::MAX),
            "core must be one component"
        );
    }
}
