//! Kronecker (R-MAT) graph generator — the Graph500 reference generator.
//!
//! Generates `edge_factor · 2^scale` directed arcs by recursively dropping
//! each arc into one of four quadrants with probabilities (A, B, C, D);
//! Graph500 uses (0.57, 0.19, 0.19, 0.05), producing the heavy-tailed
//! small-world structure of `GAP_kron` / `GAP_twitter`. The ETL then
//! symmetrizes and dedups exactly as the paper describes.

use crate::graph::builder::{EtlStats, GraphBuilder};
use crate::graph::csr::{Csr, VertexId};
use crate::util::prng::Xoshiro256StarStar;

/// Parameters of the Kronecker generator.
#[derive(Clone, Copy, Debug)]
pub struct KroneckerParams {
    /// Graph has `2^scale` vertices.
    pub scale: u32,
    /// Directed arcs generated = `edge_factor * 2^scale`.
    pub edge_factor: u32,
    /// Quadrant probability A (Graph500: 0.57).
    pub a: f64,
    /// Quadrant probability B (Graph500: 0.19).
    pub b: f64,
    /// Quadrant probability C (Graph500: 0.19; D = 1−A−B−C).
    pub c: f64,
    /// Noise added per recursion level to smooth the degree distribution
    /// (0 = classic R-MAT; Graph500 "noise" variant uses ~0.1).
    pub noise: f64,
    /// Randomly permute vertex ids so locality does not leak into
    /// partitioning (Graph500 mandates this).
    pub permute: bool,
}

impl KroneckerParams {
    /// Graph500 defaults at a given scale and edge factor.
    pub fn graph500(scale: u32, edge_factor: u32) -> Self {
        Self {
            scale,
            edge_factor,
            a: 0.57,
            b: 0.19,
            c: 0.19,
            noise: 0.0,
            permute: true,
        }
    }
}

/// Generate a symmetrized, deduplicated Kronecker graph.
pub fn kronecker(p: KroneckerParams, seed: u64) -> (Csr, EtlStats) {
    assert!(p.scale < 32, "scale must stay below 32 for u32 vertex ids");
    assert!(p.a + p.b + p.c <= 1.0 + 1e-9, "A+B+C must be <= 1");
    let n: usize = 1usize << p.scale;
    let m: usize = n * p.edge_factor as usize;
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);

    // Optional relabeling permutation.
    let perm: Option<Vec<VertexId>> = if p.permute {
        let mut ids: Vec<VertexId> = (0..n as VertexId).collect();
        rng.shuffle(&mut ids);
        Some(ids)
    } else {
        None
    };

    let mut builder = GraphBuilder::new(n);
    builder.reserve(m);
    for _ in 0..m {
        let (mut u, mut v) = (0u32, 0u32);
        // Per-edge multiplicative noise keeps expectation (A,B,C,D).
        for level in 0..p.scale {
            let (mut a, mut b, mut c) = (p.a, p.b, p.c);
            if p.noise > 0.0 {
                // Symmetric noise on A<->D, B<->C, renormalized.
                let na = 1.0 + p.noise * (2.0 * rng.next_f64() - 1.0);
                let nb = 1.0 + p.noise * (2.0 * rng.next_f64() - 1.0);
                a *= na;
                b *= nb;
                c *= 2.0 - nb;
                let d = (1.0 - p.a - p.b - p.c) * (2.0 - na);
                let sum = a + b + c + d;
                a /= sum;
                b /= sum;
                c /= sum;
            }
            let r = rng.next_f64();
            let bit = 1u32 << (p.scale - 1 - level);
            if r < a {
                // top-left quadrant: no bits set
            } else if r < a + b {
                v |= bit;
            } else if r < a + b + c {
                u |= bit;
            } else {
                u |= bit;
                v |= bit;
            }
        }
        let (u, v) = match &perm {
            Some(pm) => (pm[u as usize], pm[v as usize]),
            None => (u, v),
        };
        builder.add_edge(u, v);
    }
    builder.build_undirected()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_parameters() {
        let p = KroneckerParams::graph500(10, 8);
        let (g, stats) = kronecker(p, 1);
        assert_eq!(g.num_vertices(), 1024);
        assert_eq!(stats.raw_arcs, 8 * 1024);
        // After dedup + symmetrization the arc count is bounded by 2*raw.
        assert!(g.num_edges() <= 2 * stats.raw_arcs);
        assert!(g.num_edges() > 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = KroneckerParams::graph500(8, 4);
        let (g1, _) = kronecker(p, 99);
        let (g2, _) = kronecker(p, 99);
        assert_eq!(g1, g2);
        let (g3, _) = kronecker(p, 100);
        assert_ne!(g1, g3);
    }

    #[test]
    fn skewed_degree_distribution() {
        // R-MAT with Graph500 params is heavy-tailed: max degree should be
        // far above the mean.
        let p = KroneckerParams {
            permute: false,
            ..KroneckerParams::graph500(12, 16)
        };
        let (g, _) = kronecker(p, 5);
        let mean = g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(
            (g.max_degree() as f64) > 8.0 * mean,
            "max {} vs mean {mean}",
            g.max_degree()
        );
    }

    #[test]
    fn permutation_preserves_structure_size() {
        let base = KroneckerParams::graph500(9, 8);
        let (gp, _) = kronecker(KroneckerParams { permute: true, ..base }, 7);
        let (gn, _) = kronecker(KroneckerParams { permute: false, ..base }, 7);
        // Same number of vertices; edge counts may differ slightly because
        // dedup collisions depend on labels, but within a few percent.
        assert_eq!(gp.num_vertices(), gn.num_vertices());
        let (a, b) = (gp.num_edges() as f64, gn.num_edges() as f64);
        assert!((a - b).abs() / b < 0.05, "a={a} b={b}");
    }

    #[test]
    fn symmetric_output() {
        let (g, _) = kronecker(KroneckerParams::graph500(8, 8), 3);
        for u in 0..g.num_vertices() as u32 {
            for &v in g.neighbors(u) {
                assert!(g.has_edge(v, u), "missing mirror of ({u},{v})");
            }
        }
    }
}
