//! Synthetic graph generators.
//!
//! The paper evaluates on SuiteSparse graphs with 1–7 B edges; those are
//! substituted here by scaled-down analogs from the same generator
//! families (DESIGN.md §7): Kronecker/RMAT (Graph500, `GAP_kron`,
//! `GAP_twitter`), uniform random (`GAP_urand`), and a power-law web-like
//! generator with an optional long path tail (`Webbase-2001`'s pathological
//! diameter). Structured graphs (path, grid, star, complete, binary tree)
//! support tests with analytically known BFS distances.

pub mod kronecker;
pub mod structured;
pub mod suite;
pub mod urand;
pub mod weblike;

pub use kronecker::{kronecker, KroneckerParams};
pub use structured::{binary_tree, complete, grid2d, path, star};
pub use suite::{table1_suite, GraphSpec};
pub use urand::uniform_random;
pub use weblike::{weblike, WeblikeParams};
