//! Graph property analysis: degree statistics, connected components, and
//! pseudo-diameter — the columns of the paper's Table 1 that describe the
//! inputs (|V|, |E|, average diameter) plus the "90–95 % of vertices are in
//! the largest component" observation the root-sampling protocol relies on.

use super::csr::{Csr, VertexId};
use crate::bfs::serial::serial_bfs;

/// Degree distribution summary.
#[derive(Clone, Debug)]
pub struct DegreeStats {
    /// Minimum degree.
    pub min: u32,
    /// Maximum degree.
    pub max: u32,
    /// Mean degree.
    pub mean: f64,
    /// Histogram over log2 bins: `hist[i]` counts vertices with degree in
    /// `[2^i, 2^(i+1))`; `hist[0]` also counts degree 0..2.
    pub log2_hist: Vec<u64>,
}

/// Compute degree statistics.
pub fn degree_stats(g: &Csr) -> DegreeStats {
    let n = g.num_vertices();
    if n == 0 {
        return DegreeStats { min: 0, max: 0, mean: 0.0, log2_hist: vec![] };
    }
    let mut min = u32::MAX;
    let mut max = 0u32;
    let mut hist = vec![0u64; 33];
    for v in 0..n as VertexId {
        let d = g.degree(v);
        min = min.min(d);
        max = max.max(d);
        let bin = if d <= 1 { 0 } else { 32 - (d - 1).leading_zeros() } as usize;
        hist[bin] += 1;
    }
    while hist.len() > 1 && *hist.last().unwrap() == 0 {
        hist.pop();
    }
    DegreeStats {
        min,
        max,
        mean: g.num_edges() as f64 / n as f64,
        log2_hist: hist,
    }
}

/// Connected-components result.
#[derive(Clone, Debug)]
pub struct Components {
    /// Component label per vertex.
    pub label: Vec<u32>,
    /// Size of each component, indexed by label.
    pub sizes: Vec<u64>,
}

impl Components {
    /// Number of components.
    pub fn count(&self) -> usize {
        self.sizes.len()
    }

    /// Label of the largest component.
    pub fn largest(&self) -> u32 {
        self.sizes
            .iter()
            .enumerate()
            .max_by_key(|(_, &s)| s)
            .map(|(i, _)| i as u32)
            .unwrap_or(0)
    }

    /// Fraction of vertices in the largest component.
    pub fn largest_fraction(&self) -> f64 {
        let total: u64 = self.sizes.iter().sum();
        if total == 0 {
            return 0.0;
        }
        self.sizes[self.largest() as usize] as f64 / total as f64
    }
}

/// Label connected components by repeated BFS (undirected graphs).
pub fn connected_components(g: &Csr) -> Components {
    let n = g.num_vertices();
    let mut label = vec![u32::MAX; n];
    let mut sizes = Vec::new();
    let mut queue: Vec<VertexId> = Vec::new();
    for s in 0..n as VertexId {
        if label[s as usize] != u32::MAX {
            continue;
        }
        let c = sizes.len() as u32;
        let mut size = 0u64;
        label[s as usize] = c;
        queue.clear();
        queue.push(s);
        while let Some(v) = queue.pop() {
            size += 1;
            for &u in g.neighbors(v) {
                if label[u as usize] == u32::MAX {
                    label[u as usize] = c;
                    queue.push(u);
                }
            }
        }
        sizes.push(size);
    }
    Components { label, sizes }
}

/// Pseudo-diameter via the double-sweep heuristic: BFS from `start`, then
/// BFS from the farthest vertex found; the second eccentricity is a lower
/// bound that is exact on trees and very tight on real graphs. This is the
/// "Ave. Diam." column of Table 1. An isolated `start` is replaced by the
/// max-degree vertex (so permuted Kronecker graphs don't report 0).
pub fn pseudo_diameter(g: &Csr, start: VertexId) -> u32 {
    if g.num_vertices() == 0 {
        return 0;
    }
    let start = if g.degree(start) == 0 {
        (0..g.num_vertices() as VertexId)
            .max_by_key(|&v| g.degree(v))
            .unwrap()
    } else {
        start
    };
    let d1 = serial_bfs(g, start);
    let far = farthest(&d1).unwrap_or(start);
    let d2 = serial_bfs(g, far);
    d2.iter().filter(|&&x| x != u32::MAX).max().copied().unwrap_or(0)
}

fn farthest(dist: &[u32]) -> Option<VertexId> {
    dist.iter()
        .enumerate()
        .filter(|(_, &d)| d != u32::MAX)
        .max_by_key(|(_, &d)| d)
        .map(|(v, _)| v as VertexId)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::structured::{complete, grid2d, path, star};
    use crate::graph::gen::urand::uniform_random;

    #[test]
    fn degree_stats_star() {
        let g = star(101);
        let s = degree_stats(&g);
        assert_eq!(s.max, 100);
        assert_eq!(s.min, 1);
        assert!((s.mean - 200.0 / 101.0).abs() < 1e-9);
    }

    #[test]
    fn log2_hist_sums_to_n() {
        let (g, _) = uniform_random(500, 8, 3);
        let s = degree_stats(&g);
        assert_eq!(s.log2_hist.iter().sum::<u64>(), 500);
    }

    #[test]
    fn components_two_islands() {
        use crate::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(6);
        b.add_edges(&[(0, 1), (1, 2), (3, 4)]);
        let (g, _) = b.build_undirected();
        let c = connected_components(&g);
        assert_eq!(c.count(), 3); // {0,1,2}, {3,4}, {5}
        assert_eq!(c.sizes[c.largest() as usize], 3);
        assert!((c.largest_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(c.label[0], c.label[2]);
        assert_ne!(c.label[0], c.label[3]);
    }

    #[test]
    fn components_connected_random() {
        let (g, _) = uniform_random(300, 16, 5);
        let c = connected_components(&g);
        // ef=16 uniform is connected whp; largest fraction ~1.
        assert!(c.largest_fraction() > 0.99);
    }

    #[test]
    fn pseudo_diameter_exact_on_path() {
        let g = path(64);
        // Start in the middle; double sweep must still find 63.
        assert_eq!(pseudo_diameter(&g, 31), 63);
    }

    #[test]
    fn pseudo_diameter_grid() {
        let g = grid2d(5, 9);
        assert_eq!(pseudo_diameter(&g, 22), 4 + 8);
    }

    #[test]
    fn pseudo_diameter_complete() {
        let g = complete(10);
        assert_eq!(pseudo_diameter(&g, 0), 1);
    }
}
