//! The `.bbfs` **v2** on-disk graph container: compressed, validated,
//! memory-mappable — the storage layer behind plan warm-start.
//!
//! # Why
//!
//! The paper's headline graph is scale-29 Kronecker (0.5 B vertices, 4 B
//! edges). The v1 snapshot is raw CSR — 8 bytes per offset, 4 per edge —
//! and loading it rebuilds every in-memory array up front: a server
//! restart is O(E). v2 gap-encodes adjacency with LEB128 varints
//! (web-like graphs compress 3–5×, more after degree-sort relabeling),
//! splits vertices into fixed-size blocks with a byte/edge index, and
//! page-aligns the data section so the file can be `mmap`ed and decoded
//! lazily, block by block, on first touch.
//!
//! # Layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//!      0     8  magic "BBFSCSR2"
//!      8     4  version        = 2
//!     12     4  flags          (bit 0: permutation section present)
//!     16     8  n              vertex count (<= u32::MAX)
//!     24     8  m              directed arc count
//!     32     4  block_size     vertices per block (>= 1)
//!     36     4  num_blocks     = ceil(n / block_size)
//!     40     8  index_off      = 72 (immediately after this header)
//!     48     8  perm_off       0, or 72 + 16*(num_blocks+1)
//!     56     8  data_off       4096-aligned end of index/perm sections
//!     64     8  file_len       total container length (validated)
//! ```
//!
//! **Block index** at `index_off`: `num_blocks + 1` entries of
//! `{ data_start: u64 (relative to data_off), first_edge: u64 }`,
//! sentinel-terminated — the last entry is `(data_len, m)`, so both the
//! byte span and the edge span of block `b` are `index[b+1] - index[b]`.
//!
//! **Permutation** (iff flag bit 0): `n × u32` — entry `i` is the
//! *original* id of relabeled vertex `i` (new→old).
//!
//! **Data** at `data_off` (zero-padded gap before it): per block, first
//! the varint degree of every vertex in the block (so degree-only decode
//! — what 1D partition cuts need — never touches adjacency bytes), then
//! each vertex's sorted adjacency as varint(first neighbor) followed by
//! varint gaps (duplicates encode as gap 0).
//!
//! The writer and this loader are mirrored line-for-line in
//! `python/bench_protocol_port.py`; the committed `BENCH_engine.json`
//! `storage` section cross-validates the two byte-for-byte.

mod loader;
mod source;
pub mod varint;
mod writer;

pub use loader::{GraphStore, StoreCounters};
pub use source::{FileSource, MemSource, SlabSource};
#[cfg(unix)]
pub use source::MmapSource;
pub use writer::{encode_store, v1_snapshot_bytes, write_store, EncodedStore, StoreWriteOptions};

use crate::graph::csr::VertexId;

/// v2 container magic.
pub const V2_MAGIC: &[u8; 8] = b"BBFSCSR2";
/// Fixed header length in bytes.
pub const HEADER_LEN: u64 = 72;
/// Alignment of the data section — one page, so `mmap` serves block
/// payloads without copying across page boundaries on load.
pub const DATA_ALIGN: u64 = 4096;
/// Default vertices per block.
pub const BLOCK_SIZE_DEFAULT: u32 = 1024;

/// Typed storage-layer error. Corrupt or hostile container bytes always
/// surface as one of these — the loader has no panicking path.
#[derive(Debug)]
pub enum StoreError {
    /// Underlying I/O failure (open, read, write).
    Io(std::io::Error),
    /// The container bytes are malformed: bad magic/version, declared
    /// sizes disagreeing with the actual file, non-monotonic index,
    /// out-of-range ids, truncated or overlong varints, …
    Corrupt(String),
    /// The request or options are invalid for this store (bad row range,
    /// zero block size, graph too large to encode).
    Invalid(String),
    /// Write-side: an adjacency run violated the sorted-ascending CSR
    /// invariant, which gap encoding cannot represent.
    UnsortedAdjacency {
        /// The vertex whose adjacency run is out of order.
        vertex: VertexId,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "store i/o error: {e}"),
            StoreError::Corrupt(msg) => write!(f, "corrupt .bbfs v2 container: {msg}"),
            StoreError::Invalid(msg) => write!(f, "invalid store request: {msg}"),
            StoreError::UnsortedAdjacency { vertex } => {
                write!(f, "adjacency of vertex {vertex} is not sorted ascending")
            }
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}
