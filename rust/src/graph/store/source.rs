//! Byte sources backing a [`GraphStore`](super::GraphStore): the
//! [`SlabSource`] trait plus three std-only implementations — in-memory
//! bytes (tests, benches), positioned file reads (`pread(2)`, the
//! dependency-free default), and a real `mmap(2)` mapping behind a small
//! `unsafe` seam.

use std::fs::File;
use std::io;

/// Random-access byte source for the v2 container. Implementations must
/// be cheap to read from concurrently — the lazy slab decoder calls
/// [`read_at`](SlabSource::read_at) from multiple plan-materialization
/// threads.
pub trait SlabSource: Send + Sync + std::fmt::Debug {
    /// Total length in bytes.
    fn len(&self) -> u64;

    /// Whether the source is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fill `buf` from `offset`. Errors (rather than panics) on any read
    /// past the end — the loader treats that as a truncated file.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()>;
}

/// An owned in-memory byte buffer. Used by tests and the bench protocol,
/// where the container never touches disk.
#[derive(Debug)]
pub struct MemSource(pub Vec<u8>);

impl SlabSource for MemSource {
    fn len(&self) -> u64 {
        self.0.len() as u64
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        let start = usize::try_from(offset)
            .ok()
            .filter(|&s| s <= self.0.len())
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "read past end"))?;
        let end = start
            .checked_add(buf.len())
            .filter(|&e| e <= self.0.len())
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "read past end"))?;
        buf.copy_from_slice(&self.0[start..end]);
        Ok(())
    }
}

/// Positioned reads against an open file — `pread(2)` on unix, so no seek
/// state is shared and concurrent block loads need no lock. This is the
/// default source: lazy, dependency-free, works on any filesystem.
#[derive(Debug)]
pub struct FileSource {
    file: File,
    len: u64,
}

impl FileSource {
    /// Open `file` as a source, capturing its current length.
    pub fn new(file: File) -> io::Result<Self> {
        let len = file.metadata()?.len();
        Ok(Self { file, len })
    }
}

impl SlabSource for FileSource {
    fn len(&self) -> u64 {
        self.len
    }

    #[cfg(unix)]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.read_exact_at(buf, offset)
    }

    #[cfg(not(unix))]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        // No positioned-read API: clone the handle (shares the inode, not
        // the cursor on Windows via seek_read; elsewhere fall back to a
        // fresh seek on a duplicated descriptor).
        use std::io::{Read, Seek, SeekFrom};
        let mut f = self.file.try_clone()?;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

/// A read-only `mmap(2)` of the whole container. The page-aligned data
/// section means block payloads are served straight from the page cache;
/// cold blocks fault in on first touch instead of being deserialized up
/// front.
///
/// This is the one `unsafe` seam in the storage layer: the syscalls are
/// declared directly (std already links libc) and the mapping is private
/// + read-only, so the only soundness requirement is that nobody
/// truncates the file while mapped — same contract as every mmap reader.
#[cfg(unix)]
#[derive(Debug)]
pub struct MmapSource {
    ptr: *mut u8,
    len: usize,
}

#[cfg(unix)]
mod sys {
    //! Minimal direct bindings for `mmap`/`munmap`; std links libc so no
    //! crate dependency is needed.
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

#[cfg(unix)]
impl MmapSource {
    /// Map `file` read-only. Empty files get a valid zero-length source
    /// without calling `mmap` (which rejects length 0).
    pub fn new(file: &File) -> io::Result<Self> {
        use std::os::fd::AsRawFd;
        let len = usize::try_from(file.metadata()?.len())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "file too large to map"))?;
        if len == 0 {
            return Ok(Self { ptr: std::ptr::null_mut(), len: 0 });
        }
        // SAFETY: requesting a fresh private read-only mapping of a file
        // we hold open; the kernel picks the address. We never hand out
        // `&[u8]` views that outlive `self`, and Drop unmaps exactly the
        // region returned here.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { ptr: ptr.cast(), len })
    }

    fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
        // bytes (established in `new`, released only in Drop).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

// SAFETY: the mapping is read-only and private; sharing the pointer
// across threads is no different from sharing a `&[u8]`.
#[cfg(unix)]
unsafe impl Send for MmapSource {}
#[cfg(unix)]
unsafe impl Sync for MmapSource {}

#[cfg(unix)]
impl Drop for MmapSource {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            // SAFETY: unmapping the exact region mapped in `new`.
            unsafe {
                sys::munmap(self.ptr.cast(), self.len);
            }
        }
    }
}

#[cfg(unix)]
impl SlabSource for MmapSource {
    fn len(&self) -> u64 {
        self.len as u64
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        let data = self.as_slice();
        let start = usize::try_from(offset)
            .ok()
            .filter(|&s| s <= data.len())
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "read past end"))?;
        let end = start
            .checked_add(buf.len())
            .filter(|&e| e <= data.len())
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "read past end"))?;
        buf.copy_from_slice(&data[start..end]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_source_bounds_checked() {
        let src = MemSource(vec![1, 2, 3, 4]);
        let mut buf = [0u8; 2];
        src.read_at(1, &mut buf).unwrap();
        assert_eq!(buf, [2, 3]);
        assert!(src.read_at(3, &mut buf).is_err());
        assert!(src.read_at(u64::MAX, &mut buf).is_err());
        assert!(!src.is_empty());
    }
}
