//! `.bbfs` v2 encoder: gap-compressed blocks, block index, optional
//! degree-sort permutation, page-aligned data section.
//!
//! The byte layout is specified in the [module docs](super) and mirrored
//! line-for-line by `python/bench_protocol_port.py` — any change here must
//! land in both, or `bench-protocol --check` drifts.

use super::varint::encode_varint;
use super::{StoreError, BLOCK_SIZE_DEFAULT, DATA_ALIGN, HEADER_LEN, V2_MAGIC};
use crate::graph::csr::Csr;
use crate::partition::relabel::{apply_relabeling, degree_sort_relabeling, Relabeling};

/// Options for [`encode_store`] / [`write_store`].
#[derive(Clone, Copy, Debug)]
pub struct StoreWriteOptions {
    /// Apply the degree-sort relabeling before encoding (high-degree
    /// vertices first). Improves both gap compression and cache locality
    /// on skewed graphs; the permutation is stored so results unmap
    /// transparently.
    pub relabel: bool,
    /// Vertices per block. Smaller blocks mean finer lazy loading but a
    /// larger index.
    pub block_size: u32,
}

impl Default for StoreWriteOptions {
    fn default() -> Self {
        Self { relabel: false, block_size: BLOCK_SIZE_DEFAULT }
    }
}

/// Result of encoding: the full container bytes plus the permutation that
/// was applied (present iff `relabel` was requested).
#[derive(Debug)]
pub struct EncodedStore {
    /// The complete `.bbfs` v2 file image.
    pub bytes: Vec<u8>,
    /// The stored relabeling, if the graph was permuted before encoding.
    pub relabeling: Option<Relabeling>,
}

/// Size in bytes of the uncompressed `.bbfs` v1 snapshot of `g` —
/// the baseline for compression-ratio reporting.
pub fn v1_snapshot_bytes(g: &Csr) -> u64 {
    24 + 8 * (g.num_vertices() as u64 + 1) + 4 * g.num_edges()
}

fn align_up(x: u64, align: u64) -> u64 {
    x.div_ceil(align) * align
}

/// Encode `g` into a `.bbfs` v2 container image.
///
/// Fails with a typed error (never panics) if `n` exceeds the `u32`
/// vertex-id space or an adjacency run is not sorted ascending — the CSR
/// invariant every constructor in this crate maintains, re-checked here
/// because gap encoding silently corrupts on violation.
pub fn encode_store(g: &Csr, opts: StoreWriteOptions) -> Result<EncodedStore, StoreError> {
    if opts.block_size == 0 {
        return Err(StoreError::Invalid("block_size must be >= 1".into()));
    }
    if g.num_vertices() > u32::MAX as usize {
        return Err(StoreError::Invalid(format!(
            "{} vertices exceed the u32 id space",
            g.num_vertices()
        )));
    }
    let (graph, relabeling) = if opts.relabel {
        let r = degree_sort_relabeling(g);
        (apply_relabeling(g, &r), Some(r))
    } else {
        (g.clone(), None)
    };

    let n = graph.num_vertices();
    let m = graph.num_edges();
    let bs = opts.block_size as usize;
    let num_blocks = n.div_ceil(bs);

    // Per-block payloads: degree stream first (so degree-only decode
    // never touches adjacency bytes), then per-vertex gap-encoded lists.
    let mut data = Vec::new();
    let mut index: Vec<(u64, u64)> = Vec::with_capacity(num_blocks + 1);
    let mut edges_before: u64 = 0;
    for b in 0..num_blocks {
        index.push((data.len() as u64, edges_before));
        let lo = b * bs;
        let hi = ((b + 1) * bs).min(n);
        for v in lo..hi {
            encode_varint(u64::from(graph.degree(v as u32)), &mut data);
        }
        for v in lo..hi {
            let ns = graph.neighbors(v as u32);
            edges_before += ns.len() as u64;
            let mut prev: Option<u32> = None;
            for &w in ns {
                match prev {
                    None => encode_varint(u64::from(w), &mut data),
                    Some(p) if w >= p => encode_varint(u64::from(w - p), &mut data),
                    Some(_) => return Err(StoreError::UnsortedAdjacency { vertex: v as u32 }),
                }
                prev = Some(w);
            }
        }
    }
    index.push((data.len() as u64, m));
    debug_assert_eq!(edges_before, m);

    let flags: u32 = if relabeling.is_some() { 1 } else { 0 };
    let index_len = 16 * (num_blocks as u64 + 1);
    let perm_len = if relabeling.is_some() { 4 * n as u64 } else { 0 };
    let perm_off = if relabeling.is_some() { HEADER_LEN + index_len } else { 0 };
    let data_off = align_up(HEADER_LEN + index_len + perm_len, DATA_ALIGN);
    let file_len = data_off + data.len() as u64;

    let mut out = Vec::with_capacity(file_len as usize);
    out.extend_from_slice(V2_MAGIC);
    out.extend_from_slice(&2u32.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&(n as u64).to_le_bytes());
    out.extend_from_slice(&m.to_le_bytes());
    out.extend_from_slice(&opts.block_size.to_le_bytes());
    out.extend_from_slice(&(num_blocks as u32).to_le_bytes());
    out.extend_from_slice(&HEADER_LEN.to_le_bytes());
    out.extend_from_slice(&perm_off.to_le_bytes());
    out.extend_from_slice(&data_off.to_le_bytes());
    out.extend_from_slice(&file_len.to_le_bytes());
    debug_assert_eq!(out.len() as u64, HEADER_LEN);
    for &(start, first_edge) in &index {
        out.extend_from_slice(&start.to_le_bytes());
        out.extend_from_slice(&first_edge.to_le_bytes());
    }
    if let Some(r) = &relabeling {
        for &old in &r.old_id {
            out.extend_from_slice(&old.to_le_bytes());
        }
    }
    out.resize(data_off as usize, 0);
    out.extend_from_slice(&data);
    debug_assert_eq!(out.len() as u64, file_len);

    Ok(EncodedStore { bytes: out, relabeling })
}

/// Encode `g` and write the container to `path`. Returns the encoding
/// (bytes still in memory) so callers can report sizes without re-reading.
///
/// Crash-consistent: published with
/// [`crate::util::fsio::atomic_write`] (write-tmp → fsync → rename), so a
/// crashed writer never leaves a torn container that `open` would have to
/// reject.
pub fn write_store(
    g: &Csr,
    path: &std::path::Path,
    opts: StoreWriteOptions,
) -> Result<EncodedStore, StoreError> {
    let enc = encode_store(g, opts)?;
    crate::util::fsio::atomic_write(path, &enc.bytes)?;
    Ok(enc)
}
