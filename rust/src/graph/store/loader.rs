//! Validated `.bbfs` v2 loader: structural validation at open, lazy
//! block decoding behind [`SlabSource`], and decode counters that make
//! the cold-vs-warm-start gap observable in the bench protocol.

use std::sync::atomic::{AtomicU64, Ordering};

use super::source::{FileSource, MemSource, SlabSource};
use super::varint::decode_varint;
use super::{StoreError, DATA_ALIGN, HEADER_LEN, V2_MAGIC};
use crate::graph::csr::{Csr, CsrSlab, VertexId};
use crate::partition::relabel::Relabeling;

/// Snapshot of a store's decode counters. All three are cumulative since
/// open; the bench protocol records them at load time and again after
/// materialization, and the warm-start acceptance check requires the
/// load-time numbers to be **zero**.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Vertex-degree varints decoded (one per vertex per degree pass).
    pub degree_entries_decoded: u64,
    /// Adjacency varints decoded — first-neighbor ids and gaps, including
    /// any decoded only to skip or column-filter past them.
    pub edges_decoded: u64,
    /// Block payloads fetched from the source.
    pub blocks_decoded: u64,
}

#[derive(Clone, Copy, Debug)]
struct IndexEntry {
    /// Payload start, relative to the data section.
    data_start: u64,
    /// Global edge index of the block's first adjacency entry.
    first_edge: u64,
}

/// An open, validated `.bbfs` v2 container.
///
/// Opening reads and validates only the header, block index, and optional
/// permutation — **no adjacency bytes**. Adjacency is decoded on demand,
/// per block, via [`decode_rows`](GraphStore::decode_rows) and friends;
/// every decode path bound-checks ids and payload lengths so a corrupt
/// file surfaces as a typed [`StoreError`], never a panic.
#[derive(Debug)]
pub struct GraphStore {
    source: Box<dyn SlabSource>,
    n: usize,
    m: u64,
    block_size: u32,
    data_off: u64,
    index: Vec<IndexEntry>,
    perm_old_id: Option<Vec<VertexId>>,
    fingerprint: u64,
    degree_entries_decoded: AtomicU64,
    edges_decoded: AtomicU64,
    blocks_decoded: AtomicU64,
}

fn corrupt(msg: impl Into<String>) -> StoreError {
    StoreError::Corrupt(msg.into())
}

fn fnv1a64(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64 offset basis — the fingerprint seed.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

impl GraphStore {
    /// Open a container file with lazy `pread`-backed block loading.
    pub fn open(path: &std::path::Path) -> Result<Self, StoreError> {
        let file = std::fs::File::open(path)?;
        Self::from_source(Box::new(FileSource::new(file)?))
    }

    /// Open a container file through a read-only `mmap(2)` mapping, so
    /// block payloads are served from the page cache. Falls back to
    /// `pread` on non-unix targets.
    pub fn open_mmap(path: &std::path::Path) -> Result<Self, StoreError> {
        #[cfg(unix)]
        {
            let file = std::fs::File::open(path)?;
            let src = super::source::MmapSource::new(&file)?;
            Self::from_source(Box::new(src))
        }
        #[cfg(not(unix))]
        {
            Self::open(path)
        }
    }

    /// Open a container image held in memory (tests, bench protocol).
    pub fn open_bytes(bytes: Vec<u8>) -> Result<Self, StoreError> {
        Self::from_source(Box::new(MemSource(bytes)))
    }

    /// Open from any [`SlabSource`], validating header, index, and
    /// permutation. Every declared size is checked against the actual
    /// source length **before** any allocation sized from it.
    pub fn from_source(source: Box<dyn SlabSource>) -> Result<Self, StoreError> {
        let src_len = source.len();
        if src_len < HEADER_LEN {
            return Err(corrupt(format!("file too short for v2 header: {src_len} bytes")));
        }
        let mut hdr = [0u8; HEADER_LEN as usize];
        source.read_at(0, &mut hdr)?;
        let u32_at = |off: usize| u32::from_le_bytes(hdr[off..off + 4].try_into().unwrap());
        let u64_at = |off: usize| u64::from_le_bytes(hdr[off..off + 8].try_into().unwrap());
        if &hdr[0..8] != V2_MAGIC {
            return Err(corrupt("bad magic (not a .bbfs v2 container)"));
        }
        let version = u32_at(8);
        if version != 2 {
            return Err(corrupt(format!("unsupported container version {version}")));
        }
        let flags = u32_at(12);
        if flags & !1 != 0 {
            return Err(corrupt(format!("unknown flag bits {flags:#x}")));
        }
        let n64 = u64_at(16);
        let m = u64_at(24);
        let block_size = u32_at(32);
        let num_blocks = u64::from(u32_at(36));
        let index_off = u64_at(40);
        let perm_off = u64_at(48);
        let data_off = u64_at(56);
        let file_len = u64_at(64);

        if n64 > u64::from(u32::MAX) {
            return Err(corrupt(format!("{n64} vertices exceed the u32 id space")));
        }
        let n = n64 as usize;
        if block_size == 0 {
            return Err(corrupt("block_size is 0"));
        }
        if num_blocks != n64.div_ceil(u64::from(block_size)) {
            return Err(corrupt("num_blocks does not match n / block_size"));
        }
        if index_off != HEADER_LEN {
            return Err(corrupt("index_off must follow the header"));
        }
        if file_len != src_len {
            return Err(corrupt(format!(
                "declared file length {file_len} != actual {src_len}"
            )));
        }
        let index_len = (num_blocks + 1)
            .checked_mul(16)
            .ok_or_else(|| corrupt("index length overflows"))?;
        let has_perm = flags & 1 == 1;
        let perm_len = if has_perm { 4 * n64 } else { 0 };
        let expected_perm_off = if has_perm { HEADER_LEN + index_len } else { 0 };
        if perm_off != expected_perm_off {
            return Err(corrupt("perm_off inconsistent with flags and index length"));
        }
        let sections_end = HEADER_LEN
            .checked_add(index_len)
            .and_then(|x| x.checked_add(perm_len))
            .ok_or_else(|| corrupt("section sizes overflow"))?;
        let expected_data_off = sections_end.div_ceil(DATA_ALIGN) * DATA_ALIGN;
        if data_off != expected_data_off {
            return Err(corrupt("data_off is not the aligned end of the index/perm sections"));
        }
        if data_off > file_len {
            return Err(corrupt("data section starts past end of file"));
        }
        let data_len = file_len - data_off;

        // The declared index length is now known to fit inside the actual
        // file, so the allocation below is bounded by real bytes on disk.
        if sections_end > file_len {
            return Err(corrupt("index/perm sections truncated"));
        }
        let mut index_bytes = vec![0u8; index_len as usize];
        source.read_at(HEADER_LEN, &mut index_bytes)?;
        let mut index = Vec::with_capacity(index_bytes.len() / 16);
        for chunk in index_bytes.chunks_exact(16) {
            index.push(IndexEntry {
                data_start: u64::from_le_bytes(chunk[0..8].try_into().unwrap()),
                first_edge: u64::from_le_bytes(chunk[8..16].try_into().unwrap()),
            });
        }
        if index[0].data_start != 0 || index[0].first_edge != 0 {
            return Err(corrupt("index must start at (0, 0)"));
        }
        for w in index.windows(2) {
            if w[1].data_start < w[0].data_start || w[1].first_edge < w[0].first_edge {
                return Err(corrupt("non-monotonic block index"));
            }
        }
        let sentinel = index[index.len() - 1];
        if sentinel.data_start != data_len {
            return Err(corrupt("index sentinel does not cover the data section"));
        }
        if sentinel.first_edge != m {
            return Err(corrupt("index sentinel edge count disagrees with header"));
        }

        let mut perm_old_id = None;
        let mut perm_bytes = Vec::new();
        if has_perm {
            perm_bytes = vec![0u8; perm_len as usize];
            source.read_at(perm_off, &mut perm_bytes)?;
            let mut old_id = Vec::with_capacity(n);
            for chunk in perm_bytes.chunks_exact(4) {
                let v = u32::from_le_bytes(chunk.try_into().unwrap());
                if v as usize >= n {
                    return Err(corrupt(format!("permutation entry {v} out of range")));
                }
                old_id.push(v);
            }
            let mut seen = vec![false; n];
            for &v in &old_id {
                if std::mem::replace(&mut seen[v as usize], true) {
                    return Err(corrupt(format!("duplicate permutation entry {v}")));
                }
            }
            perm_old_id = Some(old_id);
        }

        let fingerprint = fnv1a64(fnv1a64(fnv1a64(FNV_OFFSET, &hdr), &index_bytes), &perm_bytes);

        Ok(Self {
            source,
            n,
            m,
            block_size,
            data_off,
            index,
            perm_old_id,
            fingerprint,
            degree_entries_decoded: AtomicU64::new(0),
            edges_decoded: AtomicU64::new(0),
            blocks_decoded: AtomicU64::new(0),
        })
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of directed arcs.
    pub fn num_edges(&self) -> u64 {
        self.m
    }

    /// Vertices per block.
    pub fn block_size(&self) -> u32 {
        self.block_size
    }

    /// Total container length in bytes.
    pub fn file_len(&self) -> u64 {
        self.source.len()
    }

    /// Whether a degree-sort permutation is stored (ids are relabeled).
    pub fn is_relabeled(&self) -> bool {
        self.perm_old_id.is_some()
    }

    /// The stored relabeling (new→old plus its inverse), if any.
    pub fn relabeling(&self) -> Option<Relabeling> {
        self.perm_old_id.as_ref().map(|old_id| {
            let mut new_id = vec![0 as VertexId; self.n];
            for (new, &old) in old_id.iter().enumerate() {
                new_id[old as usize] = new as VertexId;
            }
            Relabeling { new_id, old_id: old_id.clone() }
        })
    }

    /// FNV-1a 64 fingerprint of the header, index, and permutation bytes.
    /// This is what a plan cache pins itself to.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// [`fingerprint`](Self::fingerprint) as fixed-width hex, for JSON
    /// (where `u64` does not survive an `f64` round-trip).
    pub fn fingerprint_hex(&self) -> String {
        format!("{:016x}", self.fingerprint)
    }

    /// Snapshot the cumulative decode counters.
    pub fn counters(&self) -> StoreCounters {
        StoreCounters {
            degree_entries_decoded: self.degree_entries_decoded.load(Ordering::Relaxed),
            edges_decoded: self.edges_decoded.load(Ordering::Relaxed),
            blocks_decoded: self.blocks_decoded.load(Ordering::Relaxed),
        }
    }

    fn block_payload(&self, b: usize) -> Result<Vec<u8>, StoreError> {
        let start = self.index[b].data_start;
        let end = self.index[b + 1].data_start;
        let mut buf = vec![0u8; (end - start) as usize];
        self.source.read_at(self.data_off + start, &mut buf)?;
        self.blocks_decoded.fetch_add(1, Ordering::Relaxed);
        Ok(buf)
    }

    /// Decode the degree stream only — O(n) varints, zero adjacency bytes
    /// touched beyond each block's degree prefix — returning the exclusive
    /// prefix-sum array (`n + 1` entries) that partition cut computation
    /// consumes directly.
    pub fn degree_prefix(&self) -> Result<Vec<u64>, StoreError> {
        let bs = self.block_size as usize;
        let mut prefix = Vec::with_capacity(self.n + 1);
        prefix.push(0u64);
        let mut total = 0u64;
        for b in 0..self.index.len() - 1 {
            let lo = b * bs;
            let hi = ((b + 1) * bs).min(self.n);
            // Degrees sit at the head of the payload; fetch only enough
            // bytes for the worst-case varint length of the degree stream.
            let start = self.index[b].data_start;
            let end = self.index[b + 1].data_start;
            let cap = ((end - start) as usize).min((hi - lo) * super::varint::MAX_VARINT_LEN);
            let mut buf = vec![0u8; cap];
            self.source.read_at(self.data_off + start, &mut buf)?;
            let mut pos = 0usize;
            let mut block_sum = 0u64;
            for _ in lo..hi {
                let d = decode_varint(&buf, &mut pos)?;
                block_sum = block_sum
                    .checked_add(d)
                    .ok_or_else(|| corrupt("degree sum overflows"))?;
                total = total.checked_add(d).ok_or_else(|| corrupt("degree sum overflows"))?;
                prefix.push(total);
            }
            let declared = self.index[b + 1].first_edge - self.index[b].first_edge;
            if block_sum != declared {
                return Err(corrupt(format!(
                    "block {b} degree sum {block_sum} != index edge span {declared}"
                )));
            }
            self.degree_entries_decoded.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        }
        if total != self.m {
            return Err(corrupt(format!("degree total {total} != header edge count {}", self.m)));
        }
        Ok(prefix)
    }

    /// Decode rows `lo..hi` into a [`CsrSlab`], optionally keeping only
    /// neighbors in `[clo, chi)` (the 2D checkerboard column filter).
    ///
    /// Validates every id against `n`, every varint against its block
    /// payload, and each block's degree sum against the index — so any
    /// corrupt payload is a typed error.
    pub fn decode_rows_filtered(
        &self,
        lo: VertexId,
        hi: VertexId,
        cols: Option<(VertexId, VertexId)>,
    ) -> Result<CsrSlab, StoreError> {
        if lo > hi || hi as usize > self.n {
            return Err(StoreError::Invalid(format!("row range {lo}..{hi} out of bounds")));
        }
        let bs = self.block_size as usize;
        let mut offsets: Vec<u64> = Vec::with_capacity((hi - lo) as usize + 1);
        offsets.push(0);
        let mut edges: Vec<VertexId> = Vec::new();
        let first_block = lo as usize / bs;
        let last_block = (hi as usize).div_ceil(bs).max(first_block);
        let mut decoded_adjacency = 0u64;
        let mut decoded_degrees = 0u64;
        for b in first_block..last_block {
            let blo = b * bs;
            let bhi = ((b + 1) * bs).min(self.n);
            let buf = self.block_payload(b)?;
            let mut pos = 0usize;
            let mut degrees = Vec::with_capacity(bhi - blo);
            let mut block_sum = 0u64;
            for _ in blo..bhi {
                let d = decode_varint(&buf, &mut pos)?;
                block_sum = block_sum
                    .checked_add(d)
                    .ok_or_else(|| corrupt("degree sum overflows"))?;
                if d > self.m {
                    return Err(corrupt(format!("degree {d} exceeds edge count {}", self.m)));
                }
                degrees.push(d);
            }
            decoded_degrees += (bhi - blo) as u64;
            let declared = self.index[b + 1].first_edge - self.index[b].first_edge;
            if block_sum != declared {
                return Err(corrupt(format!(
                    "block {b} degree sum {block_sum} != index edge span {declared}"
                )));
            }
            for (i, &d) in degrees.iter().enumerate() {
                let v = (blo + i) as VertexId;
                if v >= hi {
                    // Rows past the request: skip the rest of the block.
                    break;
                }
                let keep = v >= lo;
                let mut prev = 0u64;
                for k in 0..d {
                    let raw = decode_varint(&buf, &mut pos)?;
                    let w = if k == 0 {
                        raw
                    } else {
                        prev.checked_add(raw).ok_or_else(|| corrupt("gap overflows"))?
                    };
                    if w >= self.n as u64 {
                        return Err(corrupt(format!("neighbor {w} out of range (n={})", self.n)));
                    }
                    prev = w;
                    if keep {
                        let w = w as VertexId;
                        match cols {
                            Some((clo, chi)) if w < clo || w >= chi => {}
                            _ => edges.push(w),
                        }
                    }
                }
                decoded_adjacency += d;
                if keep {
                    offsets.push(edges.len() as u64);
                }
            }
            // Full-block decode must land exactly at the payload end —
            // trailing garbage is corruption, not slack.
            if hi as usize >= bhi && pos != buf.len() {
                return Err(corrupt(format!("block {b} has trailing bytes past its payload")));
            }
        }
        self.degree_entries_decoded.fetch_add(decoded_degrees, Ordering::Relaxed);
        self.edges_decoded.fetch_add(decoded_adjacency, Ordering::Relaxed);
        Ok(CsrSlab { first_vertex: lo, offsets, edges })
    }

    /// Decode rows `lo..hi` with all their neighbors (the 1D row slab).
    pub fn decode_rows(&self, lo: VertexId, hi: VertexId) -> Result<CsrSlab, StoreError> {
        self.decode_rows_filtered(lo, hi, None)
    }

    /// One streaming pass over the container — each block decoded exactly
    /// once, one block resident at a time — returning the **out**-degree
    /// prefix array and the **in**-degree prefix array (both `n + 1`
    /// entries).
    ///
    /// This is what the 2D *cold* build consumes: the checkerboard's
    /// column cuts need in-degrees, which only a full adjacency scan can
    /// produce, but nothing requires materializing the whole CSR to get
    /// them. Cost is `n` degree entries + `m` adjacency varints +
    /// `num_blocks` block fetches on the decode counters — the
    /// `storage` bench records exactly that to prove no block decodes
    /// twice.
    pub fn stream_degree_prefixes(&self) -> Result<(Vec<u64>, Vec<u64>), StoreError> {
        let bs = self.block_size as usize;
        let mut out_prefix = Vec::with_capacity(self.n + 1);
        out_prefix.push(0u64);
        let mut in_deg = vec![0u64; self.n];
        let mut lo = 0usize;
        while lo < self.n {
            let hi = (lo + bs).min(self.n);
            let slab = self.decode_rows_filtered(lo as VertexId, hi as VertexId, None)?;
            for w in slab.offsets.windows(2) {
                out_prefix.push(out_prefix.last().unwrap() + (w[1] - w[0]));
            }
            for &t in &slab.edges {
                in_deg[t as usize] += 1;
            }
            lo = hi;
        }
        let mut in_prefix = Vec::with_capacity(self.n + 1);
        in_prefix.push(0u64);
        for &d in &in_deg {
            in_prefix.push(in_prefix.last().unwrap() + d);
        }
        Ok((out_prefix, in_prefix))
    }

    /// Decode the whole container back into an in-memory [`Csr`] —
    /// the eager path, and the round-trip inverse of
    /// [`encode_store`](super::encode_store) (in relabeled id space when a
    /// permutation is stored).
    pub fn to_csr(&self) -> Result<Csr, StoreError> {
        let slab = self.decode_rows(0, self.n as VertexId)?;
        if slab.offsets.last() != Some(&(slab.edges.len() as u64))
            || slab.edges.len() as u64 != self.m
        {
            return Err(corrupt("decoded edge count disagrees with header"));
        }
        Ok(Csr::from_parts(slab.offsets, slab.edges))
    }
}
