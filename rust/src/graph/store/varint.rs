//! LEB128 unsigned varints — the integer wire format of the `.bbfs` v2
//! container (degrees, first-neighbor ids, adjacency gaps).
//!
//! Encoding: 7 payload bits per byte, least-significant group first, high
//! bit set on every byte except the last. A `u64` takes at most 10 bytes;
//! small gaps (the common case after degree-sort relabeling) take one.

use super::StoreError;

/// Maximum encoded length of a `u64` varint.
pub const MAX_VARINT_LEN: usize = 10;

/// Append the LEB128 encoding of `value` to `out`.
pub fn encode_varint(mut value: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (value & 0x7f) as u8;
        value >>= 7;
        if value == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Decode one LEB128 varint from `buf[*pos..]`, advancing `*pos` past it.
///
/// Returns a typed [`StoreError::Corrupt`] on truncation, on an encoding
/// longer than [`MAX_VARINT_LEN`], or on bits overflowing 64 — a hostile
/// payload can never panic the decoder.
pub fn decode_varint(buf: &[u8], pos: &mut usize) -> Result<u64, StoreError> {
    let mut value: u64 = 0;
    let mut shift: u32 = 0;
    for i in 0..MAX_VARINT_LEN {
        let Some(&byte) = buf.get(*pos + i) else {
            return Err(StoreError::Corrupt("truncated varint".into()));
        };
        let group = u64::from(byte & 0x7f);
        if shift >= 64 || (shift == 63 && group > 1) {
            return Err(StoreError::Corrupt("varint overflows u64".into()));
        }
        value |= group << shift;
        if byte & 0x80 == 0 {
            *pos += i + 1;
            return Ok(value);
        }
        shift += 7;
    }
    Err(StoreError::Corrupt("varint longer than 10 bytes".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_edge_values() {
        let mut buf = Vec::new();
        let values = [
            0u64,
            1,
            127,
            128,
            129,
            16_383,
            16_384,
            u64::from(u32::MAX),
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in &values {
            buf.clear();
            encode_varint(v, &mut buf);
            let mut pos = 0;
            assert_eq!(decode_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn rejects_truncation_and_overflow() {
        // Truncated: continuation bit set with nothing after it.
        let mut pos = 0;
        assert!(decode_varint(&[0x80], &mut pos).is_err());
        // 10 continuation bytes: longer than any valid u64 encoding.
        let mut pos = 0;
        assert!(decode_varint(&[0x80; 10], &mut pos).is_err());
        // Overflows 64 bits in the final group.
        let mut pos = 0;
        let overflow = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x7f];
        assert!(decode_varint(&overflow, &mut pos).is_err());
    }

    #[test]
    fn single_byte_small_values() {
        for v in 0u64..128 {
            let mut buf = Vec::new();
            encode_varint(v, &mut buf);
            assert_eq!(buf, vec![v as u8]);
        }
    }
}
