//! Graph ETL: the paper's input pipeline (§4 Inputs).
//!
//! "All directed graphs get converted into undirected graphs … all
//! duplicate edges and self-edges get removed." This module is that
//! pipeline: collect raw arcs → drop self-loops → symmetrize → sort →
//! dedup → CSR.

use super::csr::{Csr, VertexId};

/// Accumulates raw (possibly dirty) arcs and produces clean CSR graphs.
#[derive(Clone, Debug, Default)]
pub struct GraphBuilder {
    n: usize,
    arcs: Vec<(VertexId, VertexId)>,
}

/// Summary of what the ETL removed/added; the paper reports |E| before and
/// |Ê| after cleaning.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EtlStats {
    /// Arcs given to the builder.
    pub raw_arcs: u64,
    /// Self-loops dropped.
    pub self_loops: u64,
    /// Duplicate arcs dropped (after symmetrization).
    pub duplicates: u64,
    /// Arcs in the final symmetric CSR (2× undirected edge count).
    pub final_arcs: u64,
}

impl GraphBuilder {
    /// Builder over `n` vertices.
    pub fn new(n: usize) -> Self {
        Self { n, arcs: Vec::new() }
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Add one directed arc.
    pub fn add_edge(&mut self, u: VertexId, v: VertexId) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        self.arcs.push((u, v));
    }

    /// Add many directed arcs.
    pub fn add_edges(&mut self, es: &[(VertexId, VertexId)]) {
        self.arcs.extend_from_slice(es);
    }

    /// Reserve capacity for `m` additional arcs.
    pub fn reserve(&mut self, m: usize) {
        self.arcs.reserve(m);
    }

    /// Run the paper's ETL: drop self-loops, symmetrize, dedup, build CSR.
    pub fn build_undirected(self) -> (Csr, EtlStats) {
        let mut stats = EtlStats {
            raw_arcs: self.arcs.len() as u64,
            ..Default::default()
        };
        // Symmetrize: emit both directions, dropping self-loops.
        let mut arcs = Vec::with_capacity(self.arcs.len() * 2);
        for (u, v) in self.arcs {
            if u == v {
                stats.self_loops += 1;
                continue;
            }
            arcs.push((u, v));
            arcs.push((v, u));
        }
        // Sort + dedup.
        arcs.sort_unstable();
        let before = arcs.len() as u64;
        arcs.dedup();
        stats.duplicates = before - arcs.len() as u64;
        stats.final_arcs = arcs.len() as u64;
        (Csr::from_edges(self.n, &arcs), stats)
    }

    /// Build a *directed* CSR (dedup + self-loop removal only); used by
    /// tests that need asymmetric inputs.
    pub fn build_directed(self) -> (Csr, EtlStats) {
        let mut stats = EtlStats {
            raw_arcs: self.arcs.len() as u64,
            ..Default::default()
        };
        let mut arcs: Vec<_> = self
            .arcs
            .into_iter()
            .filter(|&(u, v)| {
                if u == v {
                    stats.self_loops += 1;
                    false
                } else {
                    true
                }
            })
            .collect();
        arcs.sort_unstable();
        let before = arcs.len() as u64;
        arcs.dedup();
        stats.duplicates = before - arcs.len() as u64;
        stats.final_arcs = arcs.len() as u64;
        (Csr::from_edges(self.n, &arcs), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetrizes() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        let (g, stats) = b.build_undirected();
        assert!(g.has_edge(1, 0), "reverse arc added");
        assert!(g.has_edge(2, 1));
        assert_eq!(g.num_edges(), 4);
        assert_eq!(stats.final_arcs, 4);
        assert_eq!(stats.self_loops, 0);
    }

    #[test]
    fn drops_self_loops() {
        let mut b = GraphBuilder::new(3);
        b.add_edges(&[(0, 0), (1, 1), (0, 1)]);
        let (g, stats) = b.build_undirected();
        assert_eq!(stats.self_loops, 2);
        assert_eq!(g.num_edges(), 2);
        assert!(!g.has_edge(0, 0));
    }

    #[test]
    fn drops_duplicates_including_mirrored() {
        let mut b = GraphBuilder::new(3);
        // (0,1) three times plus its mirror once: all collapse to one
        // undirected edge = two arcs.
        b.add_edges(&[(0, 1), (0, 1), (0, 1), (1, 0)]);
        let (g, stats) = b.build_undirected();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(stats.duplicates, 8 - 2);
    }

    #[test]
    fn directed_build_keeps_asymmetry() {
        let mut b = GraphBuilder::new(3);
        b.add_edges(&[(0, 1), (0, 1), (2, 2)]);
        let (g, stats) = b.build_directed();
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
        assert_eq!(stats.self_loops, 1);
        assert_eq!(stats.duplicates, 1);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn etl_stats_consistency_property() {
        use crate::util::propcheck::{forall, gen, Config};
        forall(Config::cases(50), "raw = final/2 + dropped (undirected)", |rng| {
            let n = gen::usize_in(rng, 1, 40);
            let m = gen::usize_in(rng, 0, 200);
            let es = gen::edge_list(rng, n, m);
            let mut b = GraphBuilder::new(n);
            b.add_edges(&es);
            let (g, s) = b.build_undirected();
            // Every surviving arc pairs with its mirror.
            let symmetric = (0..n as u32).all(|u| {
                g.neighbors(u).iter().all(|&v| g.has_edge(v, u))
            });
            let accounting =
                s.raw_arcs == m as u64 && s.final_arcs == g.num_edges();
            (symmetric && accounting, format!("n={n} m={m}"))
        });
    }
}
