//! 2D-mode equivalence suite: the checkerboard fold/expand engine must
//! produce distances identical to the 1D butterfly engine and to
//! `bfs::serial` across the whole analog graph suite — including
//! disconnected graphs, a single-vertex graph, and duplicate-edge inputs
//! — for square and non-square grids; and its *measured* per-run message
//! count must equal the analytical `Partition2D::message_volume` model
//! exactly (the "measured, not just modeled" acceptance).

use butterfly_bfs::bfs::serial::{serial_bfs, INF};
use butterfly_bfs::comm::analysis::ModeVolume;
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::csr::{Csr, VertexId};
use butterfly_bfs::graph::gen::structured::{grid2d, path, star};
use butterfly_bfs::graph::gen::table1_suite;

/// Square and non-square grid shapes exercised everywhere below.
const GRIDS: [(u32, u32); 5] = [(4, 4), (2, 8), (8, 2), (3, 3), (1, 4)];

/// Run the full three-way check on one graph/root: 2D (every grid shape)
/// == 1D butterfly == serial, plus the message-volume model.
fn check_equivalence(g: &Csr, root: VertexId, label: &str) {
    let want = serial_bfs(g, root);
    let nodes_1d = 16.min(g.num_vertices());
    let mut one_d = TraversalPlan::build(g, EngineConfig::dgx2(nodes_1d, 4))
        .unwrap()
        .session();
    let r1 = one_d.run(root).unwrap();
    one_d.assert_agreement().unwrap();
    assert_eq!(r1.dist(), &want[..], "{label}: 1D vs serial");
    for (rows, cols) in GRIDS {
        if rows as usize > g.num_vertices() || cols as usize > g.num_vertices() {
            continue;
        }
        let plan = TraversalPlan::build(g, EngineConfig::dgx2_2d(rows, cols)).unwrap();
        let mut two_d = plan.session();
        let r2 = two_d.run(root).unwrap();
        two_d.assert_agreement().unwrap();
        let m = r2.metrics();
        assert_eq!(
            r2.dist(),
            &want[..],
            "{label}: 2D {rows}x{cols} vs serial"
        );
        assert_eq!(
            r2.dist(),
            r1.dist(),
            "{label}: 2D {rows}x{cols} vs 1D"
        );
        let p2 = plan.partition().as_two_d().unwrap();
        let volume = ModeVolume {
            mode: format!("2d-{rows}x{cols} fold-expand"),
            levels: m.depth() as u64,
            modeled_messages: p2.message_volume(m.depth() as u64),
            measured_messages: m.messages(),
            measured_bytes: m.bytes(),
        };
        assert!(volume.model_matches(), "{label}: {}", volume.render());
        // The per-phase split tiles the totals on every level.
        for l in &m.levels {
            assert_eq!(l.fold_messages + l.expand_messages, l.messages);
            assert_eq!(l.fold_bytes + l.expand_bytes, l.bytes);
        }
    }
}

/// Every suite graph at tiny scale, square and non-square grids.
#[test]
fn suite_two_d_equals_one_d_equals_serial() {
    for spec in table1_suite() {
        let g = spec.generate_scaled(-7);
        check_equivalence(&g, 0, spec.name);
    }
}

/// Structured graphs from both end roots.
#[test]
fn structured_graphs_all_roots() {
    for g in [path(40), star(50), grid2d(6, 8)] {
        let last = (g.num_vertices() - 1) as VertexId;
        check_equivalence(&g, 0, "structured");
        check_equivalence(&g, last, "structured/last");
    }
}

/// Disconnected graph: unreached vertices stay INF in every mode, on
/// every node.
#[test]
fn disconnected_graph_unreached_stay_inf() {
    use butterfly_bfs::graph::builder::GraphBuilder;
    let mut b = GraphBuilder::new(40);
    for v in 1..20u32 {
        b.add_edge(0, v);
    }
    b.add_edge(30, 31); // island
    let (g, _) = b.build_undirected();
    check_equivalence(&g, 0, "disconnected");
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2_2d(4, 4))
        .unwrap()
        .session();
    let r = session.run(0).unwrap();
    assert_eq!(r.reached(), 20);
    assert_eq!(r.dist()[30], INF);
}

/// The single-vertex graph runs (only the 1×1 grid fits) and terminates
/// with distance 0 and zero communication.
#[test]
fn single_vertex_graph() {
    let g = Csr::from_edges(1, &[]);
    assert_eq!(serial_bfs(&g, 0), vec![0]);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2_2d(1, 1))
        .unwrap()
        .session();
    let r = session.run(0).unwrap();
    session.assert_agreement().unwrap();
    assert_eq!(r.dist(), &[0][..]);
    assert_eq!(r.metrics().messages(), 0);
}

/// Duplicate-edge inputs (the raw CSR constructor does not dedup):
/// parallel edges change nothing about distances in any mode.
#[test]
fn duplicate_edge_input_equivalence() {
    let mut edges = Vec::new();
    for (u, v) in [(0u32, 1u32), (1, 2), (2, 3), (3, 0), (1, 3)] {
        for _ in 0..3 {
            edges.push((u, v));
            edges.push((v, u));
        }
    }
    // A few extra vertices reachable through one (duplicated) bridge.
    edges.push((3, 4));
    edges.push((4, 3));
    edges.push((3, 4));
    edges.push((4, 3));
    edges.push((4, 5));
    edges.push((5, 4));
    let g = Csr::from_edges(6, &edges);
    check_equivalence(&g, 0, "duplicate-edges");
}

/// Batched 2D traversals across the suite: per-lane distances equal
/// serial, and the message model still holds (one schedule execution per
/// level regardless of batch width).
#[test]
fn suite_two_d_run_batch_equals_serial() {
    use butterfly_bfs::bfs::msbfs::sample_batch_roots;
    for spec in table1_suite().into_iter().take(3) {
        let g = spec.generate_scaled(-8);
        let mut roots = sample_batch_roots(&g, 8, 0x2D ^ spec.seed);
        roots.push(roots[0]); // duplicate lane rides along
        for (rows, cols) in [(4u32, 4u32), (2, 3)] {
            let plan = TraversalPlan::build(&g, EngineConfig::dgx2_2d(rows, cols)).unwrap();
            let mut session = plan.session();
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap();
            let p2 = plan.partition().as_two_d().unwrap();
            let m = b.metrics();
            assert_eq!(
                m.messages(),
                p2.message_volume(m.depth() as u64),
                "{} {rows}x{cols}",
                spec.name
            );
            for (lane, &r) in roots.iter().enumerate() {
                assert_eq!(
                    b.dist(lane),
                    &serial_bfs(&g, r)[..],
                    "{} {rows}x{cols} lane {lane}",
                    spec.name
                );
            }
        }
    }
}

/// Direction modes compose with the 2D exchange unchanged (the paper's
/// contribution-3 claim, transplanted to the comparator layout).
#[test]
fn two_d_direction_modes_equal_serial_on_suite_graph() {
    use butterfly_bfs::coordinator::config::DirectionMode;
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "kron-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    let want = serial_bfs(&g, 1);
    for direction in [
        DirectionMode::TopDown,
        DirectionMode::BottomUp,
        DirectionMode::diropt(),
    ] {
        let cfg = EngineConfig { direction, ..EngineConfig::dgx2_2d(2, 8) };
        let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
        let r = session.run(1).unwrap();
        session.assert_agreement().unwrap();
        assert_eq!(r.dist(), &want[..], "{direction:?}");
    }
}
