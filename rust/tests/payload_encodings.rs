//! Payload-encoding coverage: randomized cross-checks of the byte
//! accounting of `PayloadEncoding::{Queue, Bitmap, Auto, MaskDelta}`,
//! semantic transparency of the encoding choice inside the engine, and
//! `Bitmap::union_in` return-count properties.

use butterfly_bfs::bfs::frontier::{Bitmap, MaskFrontier};
use butterfly_bfs::bfs::msbfs::{mask_delta_bytes, mask_delta_bytes_dense, MaskDeltaStats};
use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::{
    EngineConfig, KernelVariant, PayloadEncoding, TraversalPlan,
};
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::util::propcheck::{forall, gen, Config};

/// Exact closed forms, cross-checked against each other on random
/// (queue length, vertex count) pairs.
#[test]
fn byte_accounting_cross_check() {
    forall(Config::cases(200), "payload byte accounting", |rng| {
        let v = gen::usize_in(rng, 1, 1 << 20);
        let len = gen::usize_in(rng, 0, 2 * v) as u64;
        let q = PayloadEncoding::Queue.bytes(len, v);
        let b = PayloadEncoding::Bitmap.bytes(len, v);
        let a = PayloadEncoding::Auto.bytes(len, v);
        let m = PayloadEncoding::MaskDelta.bytes(len, v);
        let ok = q == len * 4
            && b == (v as u64).div_ceil(64) * 8
            && a == q.min(b)
            && m == (len * MaskFrontier::<1>::ENTRY_BYTES).min(v as u64 * 8)
            // Bitmap is queue-length invariant; Auto is never worse than
            // either pure encoding; MaskDelta never exceeds the dense mask
            // array (64 lanes × 1 bit, i.e. 64× the bitmap bound).
            && b == PayloadEncoding::Bitmap.bytes(0, v)
            && a <= q
            && a <= b
            && m <= v as u64 * 8
            && m <= 64 * b + 64 * 8 // dense masks ≤ 64 bitmaps (word padding)
        ;
        (ok, format!("v={v} len={len} q={q} b={b} a={a} m={m}"))
    });
}

/// A `MaskFrontier` built from dense masks prices exactly like the
/// `MaskDelta` encoding's sparse branch.
#[test]
fn mask_frontier_matches_maskdelta_accounting() {
    forall(Config::cases(60), "mask frontier accounting", |rng| {
        let v = gen::usize_in(rng, 1, 500);
        let mut masks = vec![0u64; v];
        for _ in 0..gen::usize_in(rng, 0, v) {
            masks[rng.next_usize(v)] |= 1u64 << rng.next_usize(64);
        }
        let f = MaskFrontier::<1>::from_masks(&masks);
        let sparse = f.payload_bytes();
        let priced = PayloadEncoding::MaskDelta.bytes(f.len() as u64, v);
        let nonzero = masks.iter().filter(|&&m| m != 0).count();
        let ok = f.len() == nonzero
            && sparse == f.len() as u64 * MaskFrontier::<1>::ENTRY_BYTES
            && priced == sparse.min(v as u64 * 8)
            && f.to_masks(v) == masks;
        (ok, format!("v={v} entries={}", f.len()))
    });
}

/// The negotiated MS-BFS delta pricing (`mask_delta_bytes`): zero for
/// empty messages, never worse than any of its four candidate
/// serializations, and consistent under random (but invariant-respecting)
/// coalescing statistics.
#[test]
fn negotiated_mask_delta_pricing_properties() {
    forall(Config::cases(200), "mask_delta_bytes negotiation", |rng| {
        let v = gen::usize_in(rng, 1, 1 << 16);
        let entries = gen::usize_in(rng, 0, 4 * v) as u64;
        // Invariants: distinct vertices ≤ min(entries, V); distinct masks
        // ≤ entries; active lanes ≤ 64, and ≥ 1 when any entry exists.
        let distinct = gen::usize_in(rng, 0, (entries as usize).min(v)) as u64;
        let masks = gen::usize_in(rng, 0, entries as usize) as u64;
        let active = if entries == 0 {
            0
        } else {
            gen::usize_in(rng, 1, 64) as u32
        };
        let presence = (v as u64).div_ceil(64) * 8;
        // At W = 1 the word statistics are the counts themselves.
        let s = MaskDeltaStats {
            entries,
            distinct_vertices: distinct,
            distinct_masks: masks,
            active_lanes: active,
            entry_words: entries,
            vertex_words: distinct,
            group_words: masks,
        };
        let priced = mask_delta_bytes(&s, v, 1);
        let ok = if entries == 0 {
            priced == 0
        } else {
            priced <= entries * MaskFrontier::<1>::ENTRY_BYTES
                && priced <= masks * 12 + entries * 4
                && priced <= presence + distinct * 8
                && priced <= (1 + active as u64) * presence
                // Single active lane with unknown stats never exceeds two
                // bitmaps — the single-root dense bound plus presence.
                && (active != 1 || priced <= 2 * presence)
        };
        (ok, format!("v={v} e={entries} d={distinct} m={masks} a={active}"))
    });
}

/// The width-aware negotiation: every arm reprices with the lane word
/// count exactly as specified (`4 + 8W` entries, `8W`-byte packed masks)
/// while the presence-bitmap arms stay width-invariant — so a wide batch
/// with few active lanes never pays for its provisioned width.
#[test]
fn negotiated_pricing_scales_with_lane_words() {
    forall(Config::cases(120), "mask_delta_bytes width scaling", |rng| {
        let v = gen::usize_in(rng, 1, 1 << 16);
        let entries = gen::usize_in(rng, 1, 2 * v) as u64;
        let distinct = gen::usize_in(rng, 1, (entries as usize).min(v)) as u64;
        let masks = gen::usize_in(rng, 1, entries as usize) as u64;
        let presence = (v as u64).div_ceil(64) * 8;
        let mut ok = true;
        for words in [2usize, 4, 8] {
            let active = gen::usize_in(rng, 1, 64 * words) as u32;
            // Word statistics within their invariant ranges: each entry /
            // vertex / group has between 1 and W nonzero words, a
            // vertex's cells never exceed the entry words that fed them,
            // and the active cohorts must hold the active lanes.
            let aw = gen::usize_in(
                rng,
                (active as usize).div_ceil(64),
                words.min(active as usize),
            ) as u32;
            let entry_words =
                gen::usize_in(rng, entries as usize, entries as usize * words) as u64;
            let vertex_words = gen::usize_in(
                rng,
                distinct as usize,
                (distinct as usize * words).min(entry_words as usize),
            ) as u64;
            let group_words =
                gen::usize_in(rng, masks as usize, masks as usize * words) as u64;
            let s = MaskDeltaStats {
                entries,
                distinct_vertices: distinct,
                distinct_masks: masks,
                active_lanes: active,
                active_words: aw,
                entry_words,
                vertex_words,
                group_words,
            };
            let priced = mask_delta_bytes(&s, v, words);
            ok &= priced <= entries * 5 + 8 * entry_words
                && priced <= masks * 5 + 8 * group_words + entries * 4
                && priced <= aw as u64 * presence + 8 * vertex_words
                && priced <= words as u64 * presence + 8 * vertex_words
                && priced <= (1 + active as u64) * presence
                // One active lane: two bitmaps regardless of width.
                && (active != 1 || priced <= 2 * presence)
                // The dense bottom-up forms bound the full negotiation.
                && priced <= mask_delta_bytes_dense(vertex_words, aw, active, v)
                // The word-sparse forms never exceed the full-width
                // serialization a naive encoder would ship.
                && priced <= entries * (4 + 8 * words as u64) + entries;
            // All-words-nonzero stats degrade gracefully: still bounded
            // by the width-invariant lane-bitmap arm.
            let full = MaskDeltaStats {
                entry_words: entries * words as u64,
                vertex_words: distinct * words as u64,
                group_words: masks * words as u64,
                ..s
            };
            ok &= mask_delta_bytes(&full, v, words) <= (1 + active as u64) * presence;
        }
        (ok, format!("v={v} e={entries} d={distinct} m={masks}"))
    });
}

/// Every encoding produces identical distances — the encoding only changes
/// what the interconnect simulator is told about bytes, never the merge
/// semantics — and the byte totals obey Auto ≤ Queue, Auto ≤ Bitmap.
#[test]
fn encodings_semantically_transparent_in_engine() {
    let (g, _) = uniform_random(900, 8, 42);
    let want = serial_bfs(&g, 7);
    let mut bytes = Vec::new();
    for payload in [
        PayloadEncoding::Queue,
        PayloadEncoding::Bitmap,
        PayloadEncoding::Auto,
        PayloadEncoding::MaskDelta,
    ] {
        let cfg = EngineConfig { payload, ..EngineConfig::dgx2(8, 2) };
        let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
        let r = session.run(7).unwrap();
        session.assert_agreement().unwrap();
        assert_eq!(r.dist(), &want[..], "{payload:?}");
        bytes.push(r.metrics().bytes());
    }
    let (q, b, a) = (bytes[0], bytes[1], bytes[2]);
    assert!(a <= q && a <= b, "{bytes:?}");
}

/// Randomized `Bitmap::union_in` return-count properties: the return value
/// is exactly the growth in set bits, a second union is a no-op, and the
/// result is the bitwise OR.
#[test]
fn union_in_return_count_properties() {
    forall(Config::cases(100), "union_in counts", |rng| {
        let n = gen::usize_in(rng, 1, 600);
        let la = gen::usize_in(rng, 0, 80);
        let lb = gen::usize_in(rng, 0, 80);
        let qa: Vec<u32> =
            gen::vec_below(rng, la, n as u64).iter().map(|&x| x as u32).collect();
        let qb: Vec<u32> =
            gen::vec_below(rng, lb, n as u64).iter().map(|&x| x as u32).collect();
        let mut a = Bitmap::from_queue(n, &qa);
        let b = Bitmap::from_queue(n, &qb);
        let before = a.count();
        let grew = a.union_in(&b);
        let after = a.count();
        let again = a.union_in(&b);
        let self_union = {
            let snap = a.clone();
            a.union_in(&snap)
        };
        let ok = after == before + grew
            && again == 0
            && self_union == 0
            && (0..n as u32).all(|v| a.get(v) == (qa.contains(&v) || qb.contains(&v)));
        (ok, format!("n={n} |a|={} |b|={}", qa.len(), qb.len()))
    });
}

/// The `MaskDelta` 8·V accounting switchover, pinned exactly from both
/// sides: below `⌈8V/12⌉` entries the sparse `12·entries` form is priced,
/// at and above it the dense per-vertex mask array caps the cost — and
/// the priced bytes are monotone non-decreasing through the crossing.
#[test]
fn mask_delta_switchover_pinned_both_sides() {
    for v in [96usize, 97, 600, 601] {
        let cross = (v as u64 * 8).div_ceil(MaskFrontier::<1>::ENTRY_BYTES);
        let mut prev = 0;
        for e in 0..=(v as u64 + 4) {
            let priced = PayloadEncoding::MaskDelta.bytes(e, v);
            if e < cross {
                assert_eq!(priced, e * 12, "v={v} e={e}: sparse side");
                assert!(priced < v as u64 * 8);
            } else {
                assert_eq!(priced, v as u64 * 8, "v={v} e={e}: dense side");
            }
            assert!(priced >= prev, "v={v} e={e}: monotone");
            prev = priced;
        }
        // The negotiated engine pricing respects the same dense family cap
        // (presence bitmap + per-vertex masks) past the crossover.
        let presence = (v as u64).div_ceil(64) * 8;
        let dv = cross.min(v as u64);
        let negotiated = mask_delta_bytes(
            &MaskDeltaStats {
                entries: cross,
                distinct_vertices: dv,
                distinct_masks: cross,
                active_lanes: 64,
                entry_words: cross,
                vertex_words: dv,
                group_words: cross,
            },
            v,
            1,
        );
        assert!(negotiated <= presence + v as u64 * 8);
    }
}

/// Build the crossing graph: a 3-vertex path feeding a hub whose leaves
/// continue into a second path — so a batch rooted at the path start runs
/// sparse levels, then a dense (≥ ⌈8V/12⌉-entry) hub level, then sparse
/// levels again: the dense merge fallback engages and disengages within
/// one traversal.
fn hub_with_tails(leaves: u32) -> butterfly_bfs::graph::csr::Csr {
    use butterfly_bfs::graph::builder::GraphBuilder;
    // 0-1-2-3(hub); hub-leaves; leaf "leaves+3" continues 3 more hops.
    let n = 4 + leaves + 3;
    let mut b = GraphBuilder::new(n as usize);
    b.add_edge(0, 1);
    b.add_edge(1, 2);
    b.add_edge(2, 3);
    for l in 0..leaves {
        b.add_edge(3, 4 + l);
    }
    for k in 0..3 {
        b.add_edge(3 + leaves + k, 4 + leaves + k);
    }
    b.build_undirected().0
}

/// The dense-merge byte-accounting regression, re-run under every mask
/// kernel variant: the traversal crosses the 8·V switchover upward (hub
/// level) and back downward (tail levels), distances stay oracle-exact on
/// every node, the hot level's priced bytes stay strictly below the
/// unbounded sparse `12·entries` cost — and the kernel variant changes
/// *none* of the wire accounting (bytes are a property of what is sent,
/// not of how the receiver scans its merge buffers).
#[test]
fn batch_dense_fallback_crosses_switchover_both_directions() {
    use butterfly_bfs::bfs::msbfs::ms_bfs;
    let g = hub_with_tails(600);
    let v = g.num_vertices();
    let dense_entries = (v as u64 * 8).div_ceil(MaskFrontier::<1>::ENTRY_BYTES);
    let roots = vec![0u32; 64]; // duplicate roots: lanes travel together
    let want = ms_bfs(&g, &roots);
    let mut oracle_bytes: Option<Vec<u64>> = None;
    for kernel in [KernelVariant::Auto, KernelVariant::Scalar, KernelVariant::Chunked] {
        let cfg = EngineConfig { kernel, ..EngineConfig::dgx2(4, 1) };
        let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
        let b = session.run_batch(&roots).unwrap();
        session.assert_batch_agreement().unwrap();
        let m = b.metrics();
        for lane in 0..roots.len() {
            assert_eq!(b.dist(lane), want.dist(lane), "{kernel:?} lane {lane}");
        }
        // Reconstruct per-level delta entries: with 64 duplicate lanes every
        // discovery carries the full mask, so entries = discovered / 64.
        let entries: Vec<u64> = m.levels.iter().map(|l| l.discovered / 64).collect();
        let hot = entries
            .iter()
            .position(|&e| e >= dense_entries)
            .expect("a level must cross the dense threshold");
        assert!(hot > 0, "{kernel:?}: sparse levels precede the hub level");
        assert!(
            entries[hot + 1..].iter().all(|&e| e < dense_entries),
            "{kernel:?}: tail levels drop back below the threshold: {entries:?}"
        );
        assert!(
            entries[..hot].iter().all(|&e| e < dense_entries),
            "{kernel:?}: pre-hub levels are sparse: {entries:?}"
        );
        // Byte accounting at the hot level: the negotiated encoding must
        // undercut the unbounded sparse form once past the switchover.
        let hot_level = &m.levels[hot];
        let sparse_cost =
            hot_level.messages * entries[hot] * MaskFrontier::<1>::ENTRY_BYTES;
        assert!(
            hot_level.bytes < sparse_cost,
            "{kernel:?}: dense/grouped pricing caps the hot level: {} !< {sparse_cost}",
            hot_level.bytes
        );
        // And the hard ceiling: no message ever exceeds the dense mask family
        // bound (presence bitmap + one word per vertex).
        let presence = (v as u64).div_ceil(64) * 8;
        for l in &m.levels {
            assert!(
                l.bytes <= l.messages * (presence + v as u64 * 8),
                "{kernel:?} level {}",
                l.level
            );
        }
        // The kernel variant is invisible on the wire: per-level bytes are
        // identical across scalar / chunked / auto.
        let per_level: Vec<u64> = m.levels.iter().map(|l| l.bytes).collect();
        match &oracle_bytes {
            None => oracle_bytes = Some(per_level),
            Some(o) => assert_eq!(o, &per_level, "{kernel:?} changed wire bytes"),
        }
    }
}

/// The engine's per-level Bitmap payload equals the closed form for every
/// level regardless of frontier size (the paper's tight bound).
#[test]
fn bitmap_bytes_closed_form_in_engine() {
    let (g, _) = uniform_random(1000, 8, 9);
    let cfg = EngineConfig {
        payload: PayloadEncoding::Bitmap,
        ..EngineConfig::dgx2(8, 1)
    };
    let plan = TraversalPlan::build(&g, cfg).unwrap();
    let mut session = plan.session();
    let per_msg = PayloadEncoding::Bitmap.bytes(0, g.num_vertices());
    let msgs = plan.schedule().total_messages();
    let r = session.run(0).unwrap();
    for l in &r.metrics().levels {
        assert_eq!(l.bytes, per_msg * msgs, "level {}", l.level);
    }
}

// ---------------------------------------------------------------------------
// Wire-frame corpus: hostile inputs against `fault::wire::WireDelta`
// ---------------------------------------------------------------------------

use butterfly_bfs::fault::{fnv1a64, WireArm, WireDelta, WireError};
use butterfly_bfs::util::prng::Xoshiro256StarStar;

/// A random well-formed delta: sorted unique vertices, nonzero masks.
fn wire_delta(rng: &mut Xoshiro256StarStar, w: usize) -> WireDelta {
    let nv = 64 + rng.next_usize(300) as u32;
    let count = rng.next_usize(24);
    let mut verts: Vec<u32> = (0..nv).collect();
    rng.shuffle(&mut verts);
    let mut picked = verts[..count].to_vec();
    picked.sort_unstable();
    let entries = picked
        .into_iter()
        .map(|v| {
            let mut mask = vec![0u64; w];
            mask[rng.next_usize(w)] = rng.next_u64() | 1;
            (v, mask)
        })
        .collect();
    WireDelta { num_vertices: nv, lane_words: w as u8, entries }
}

/// Append the FNV-1a trailer a well-formed sender would.
fn seal(mut body: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a64(&body);
    body.extend_from_slice(&sum.to_le_bytes());
    body
}

/// A frame header with attacker-controlled fields (magic always valid).
fn header(tag: u8, lane_words: u8, num_vertices: u32, count: u64) -> Vec<u8> {
    let mut b = vec![0xBF, 0x5B, tag, lane_words];
    b.extend_from_slice(&num_vertices.to_le_bytes());
    b.extend_from_slice(&count.to_le_bytes());
    b
}

/// Every strict prefix of a valid frame must yield a typed error — never a
/// panic, never a bogus decode. The full frame must still round-trip.
#[test]
fn wire_truncation_corpus() {
    forall(Config::cases(40), "wire truncation", |rng| {
        let w = [1usize, 2, 4, 8][rng.next_usize(4)];
        let d = wire_delta(rng, w);
        for arm in WireArm::ALL {
            let bytes = d.encode(arm);
            if WireDelta::decode(&bytes).as_ref() != Ok(&d) {
                return (false, format!("{arm:?} w={w}: full frame failed"));
            }
            for cut in 0..bytes.len() {
                if WireDelta::decode(&bytes[..cut]).is_ok() {
                    return (false, format!("{arm:?} w={w}: prefix {cut} decoded"));
                }
            }
        }
        (true, String::new())
    });
}

/// Any single-bit flip anywhere in the frame is detected, and everything
/// inside the checksummed region is classed as corruption (magic flips
/// excepted — they fail even earlier). This is the detection path the
/// fault model's `Corrupt` injection relies on.
#[test]
fn wire_bitflip_corpus() {
    forall(Config::cases(12), "wire bit flips", |rng| {
        let w = [1usize, 2, 4, 8][rng.next_usize(4)];
        let d = wire_delta(rng, w);
        let arm = WireArm::ALL[rng.next_usize(4)];
        let bytes = d.encode(arm);
        for byte in 0..bytes.len() {
            for bit in 0..8 {
                let mut evil = bytes.clone();
                evil[byte] ^= 1 << bit;
                let ok = match WireDelta::decode(&evil) {
                    Err(WireError::BadMagic { .. }) => byte < 2,
                    Err(WireError::ChecksumMismatch { .. }) => true,
                    Err(e) => {
                        return (false, format!("{arm:?} byte {byte}: wrong class {e:?}"))
                    }
                    Ok(_) => false,
                };
                if !ok {
                    return (false, format!("{arm:?} w={w}: flip at byte {byte} missed"));
                }
            }
        }
        (true, String::new())
    });
}

/// Hostile declared counts (entry counts, group counts, member counts,
/// lane counts) are rejected by capacity arithmetic *before* any
/// allocation sized from them — a `u64::MAX` count must come back as a
/// typed `CountOverflow`, instantly.
#[test]
fn wire_hostile_counts_rejected_before_allocation() {
    // Sparse: count says u64::MAX entries, payload holds none.
    let frame = seal(header(0, 1, 1000, u64::MAX));
    assert!(matches!(
        WireDelta::decode(&frame),
        Err(WireError::CountOverflow { declared: u64::MAX, .. })
    ));
    // Grouped: plausible entry count, group count u32::MAX.
    let mut body = header(1, 1, 1000, 2);
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::CountOverflow { .. })
    ));
    // Grouped: valid group, member count beyond the remaining payload.
    let mut body = header(1, 1, 1000, 1);
    body.extend_from_slice(&1u32.to_le_bytes()); // one group
    body.extend_from_slice(&7u64.to_le_bytes()); // its mask
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // members
    body.extend_from_slice(&2u32.to_le_bytes()); // room for just one member
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::CountOverflow { .. })
    ));
    // LaneBitmaps: active lane count beyond 64·lane_words.
    let mut body = header(3, 1, 1000, 0);
    body.extend_from_slice(&u16::MAX.to_le_bytes());
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::LaneOutOfRange { lane: u16::MAX, lanes: 64 })
    ));
    // Presence: active-word byte naming words past lane_words.
    let mut body = header(2, 1, 64, 0);
    body.push(0b1000_0000);
    body.extend_from_slice(&[0u8; 8]); // the word-0 bitmap it promises
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::WordIndexOutOfRange { .. })
    ));
}

/// Structurally hostile frames with *valid* checksums (a malicious sender,
/// not line noise) land in the right typed error, not a panic.
#[test]
fn wire_hostile_structure_corpus() {
    // Unknown arm tag.
    let frame = seal(header(9, 1, 10, 0));
    assert!(matches!(WireDelta::decode(&frame), Err(WireError::BadArm { found: 9 })));
    // lane_words outside 1..=8.
    for lw in [0u8, 9, 255] {
        let frame = seal(header(0, lw, 10, 0));
        assert!(matches!(
            WireDelta::decode(&frame),
            Err(WireError::BadLaneWords { found }) if found == lw
        ));
    }
    // Sparse entry with a vertex at num_vertices.
    let mut body = header(0, 1, 5, 1);
    body.extend_from_slice(&5u32.to_le_bytes());
    body.extend_from_slice(&1u64.to_le_bytes());
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::VertexOutOfRange { vertex: 5, num_vertices: 5 })
    ));
    // Sparse entry with an all-zero mask (non-canonical).
    let mut body = header(0, 1, 5, 1);
    body.extend_from_slice(&3u32.to_le_bytes());
    body.extend_from_slice(&0u64.to_le_bytes());
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::EmptyMask { vertex: 3 })
    ));
    // Grouped: a group declaring zero members.
    let mut body = header(1, 1, 5, 0);
    body.extend_from_slice(&1u32.to_le_bytes()); // one group
    body.extend_from_slice(&7u64.to_le_bytes()); // mask
    body.extend_from_slice(&0u32.to_le_bytes()); // zero members
    assert!(matches!(WireDelta::decode(&seal(body)), Err(WireError::EmptyGroup)));
    // Declared count disagreeing with the decoded payload.
    let mut body = header(0, 1, 10, 2);
    body.extend_from_slice(&1u32.to_le_bytes());
    body.extend_from_slice(&1u64.to_le_bytes());
    // Declared 2, shipped 1 — the second read runs off the payload.
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::Truncated { .. } | WireError::CountOverflow { .. })
    ));
    // Well-formed payload followed by garbage the checksum covers.
    let d = WireDelta {
        num_vertices: 40,
        lane_words: 1,
        entries: vec![(3, vec![0b101]), (17, vec![1])],
    };
    let good = d.encode(WireArm::Sparse);
    let mut body = good[..good.len() - 8].to_vec();
    body.extend_from_slice(&[0xAB; 5]);
    assert!(matches!(
        WireDelta::decode(&seal(body)),
        Err(WireError::TrailingBytes { extra: 5 })
    ));
}
