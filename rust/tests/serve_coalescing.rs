//! Serve-mode integration tests: coalesced results are bit-identical to
//! per-request runs (the property the whole serving layer rests on),
//! the fairness/deadline rules hold end-to-end over a real socket, and
//! overload produces typed backpressure instead of queue collapse.

use butterfly_bfs::coordinator::{
    BatchWidth, EngineConfig, PartitionMode, SessionPool, TraversalPlan,
};
use butterfly_bfs::graph::csr::VertexId;
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::serve::{ServeConfig, Server};
use butterfly_bfs::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

// ---------- the coalescing-correctness property ----------

/// N single-root requests coalesced into one wide batch must return
/// distances bit-identical to N fresh `session.run(root)` calls. This is
/// the exact substitution the server performs, checked across both
/// partition modes, duplicate roots, and partial final batches.
#[test]
fn coalesced_batches_bit_identical_to_per_request_runs() {
    let (g, _) = uniform_random(600, 6, 23);
    let configs = [
        ("1d", EngineConfig::dgx2(4, 2)),
        (
            "2d",
            EngineConfig {
                partition: PartitionMode::TwoD { rows: 2, cols: 2 },
                ..EngineConfig::dgx2(4, 1)
            },
        ),
    ];
    for (mode, cfg) in configs {
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        // Width sweep crosses lane-word boundaries and includes the
        // partial final batch a coalescing window produces (widths that
        // are not multiples of anything), plus duplicate roots across
        // "requests" — each lane is an independent traversal even when
        // two clients ask for the same root.
        for width in [1usize, 2, 7, 64, 65, 130] {
            let roots: Vec<VertexId> = (0..width)
                .map(|i| if i % 5 == 4 { 17 } else { ((i * 53) % 600) as VertexId })
                .collect();
            let mut session = plan.session();
            let batch = session.run_batch(&roots).unwrap();
            for (lane, &root) in roots.iter().enumerate() {
                let solo = plan.session().run(root).unwrap();
                assert_eq!(
                    batch.dist(lane),
                    solo.dist(),
                    "{mode} width {width} lane {lane} root {root}: coalesced \
                     distances diverge from a per-request run"
                );
            }
        }
    }
}

/// The same property through the SessionPool (the server's actual
/// execution path), with an injected panic in between: a panicking query
/// on one thread must not perturb any later pooled result.
#[test]
fn pooled_coalescing_survives_injected_panic_bitwise() {
    let (g, _) = uniform_random(500, 5, 31);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
    let pool = SessionPool::new(Arc::clone(&plan));
    let roots: Vec<VertexId> = (0..9).map(|i| (i * 37 % 500) as VertexId).collect();
    let before = pool.acquire().run_batch(&roots).unwrap();
    let panicked = std::thread::scope(|scope| {
        scope
            .spawn(|| {
                let mut session = pool.acquire();
                session.run(1).unwrap();
                panic!("injected");
            })
            .join()
    });
    assert!(panicked.is_err());
    let after = pool.acquire().run_batch(&roots).unwrap();
    for lane in 0..roots.len() {
        assert_eq!(before.dist(lane), after.dist(lane), "lane {lane}");
        assert_eq!(before.dist(lane), plan.session().run(roots[lane]).unwrap().dist());
    }
}

// ---------- socket end-to-end ----------

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    line: String,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client {
            writer: stream.try_clone().unwrap(),
            reader: BufReader::new(stream),
            line: String::new(),
        }
    }

    fn send(&mut self, req: &Json) {
        self.writer.write_all(req.render().as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
    }

    fn recv(&mut self) -> Json {
        self.line.clear();
        let n = self.reader.read_line(&mut self.line).unwrap();
        assert!(n > 0, "server closed the connection");
        Json::parse(self.line.trim()).unwrap()
    }
}

fn query(id: u64, root: u64, targets: &[u64]) -> Json {
    let mut fields = vec![
        ("op", Json::s("query")),
        ("id", Json::u(id)),
        ("root", Json::u(root)),
    ];
    if !targets.is_empty() {
        fields.push(("targets", Json::Arr(targets.iter().map(|&t| Json::u(t)).collect())));
    }
    Json::obj(fields)
}

fn boot(
    plan: &Arc<TraversalPlan>,
    cfg: ServeConfig,
) -> (std::net::SocketAddr, std::thread::JoinHandle<Json>) {
    let server = Server::bind(Arc::clone(plan), cfg).unwrap();
    let addr = server.local_addr().unwrap();
    (addr, std::thread::spawn(move || server.run().unwrap()))
}

/// Distances over the wire match fresh in-process runs, for every
/// status-ok response of a burst of coalescible single-root queries.
#[test]
fn served_distances_match_in_process_runs() {
    let (g, _) = uniform_random(400, 5, 7);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
    let (addr, server) = boot(
        &plan,
        ServeConfig {
            coalesce_window_us: 20_000,
            max_batch: 16,
            ..ServeConfig::default()
        },
    );
    let mut c = Client::connect(addr);
    let n = 12u64;
    let targets: Vec<u64> = vec![0, 17, 399];
    for id in 0..n {
        c.send(&query(id, id * 31 % 400, &targets));
    }
    let mut seen = vec![false; n as usize];
    for _ in 0..n {
        let resp = c.recv();
        assert_eq!(resp.get("status").unwrap().as_str(), Some("ok"));
        let id = resp.get("id").unwrap().as_u64().unwrap();
        let root = resp.get("root").unwrap().as_u64().unwrap();
        assert_eq!(root, id * 31 % 400, "responses must echo their request");
        seen[id as usize] = true;
        let solo = plan.session().run(root as VertexId).unwrap();
        let dist = resp.get("dist").unwrap().as_arr().unwrap();
        for (t, d) in targets.iter().zip(dist) {
            let expect = solo.dist()[*t as usize];
            match d.as_u64() {
                Some(served) => assert_eq!(served, expect as u64, "root {root} target {t}"),
                None => assert_eq!(expect, u32::MAX, "root {root} target {t}"),
            }
        }
        let reached = solo.dist().iter().filter(|&&d| d != u32::MAX).count() as u64;
        assert_eq!(resp.get("reached").unwrap().as_u64(), Some(reached));
        // Burst of 12 with a 3 ms window and max_batch 16: at least some
        // requests must have shared a batch.
        assert!(resp.get("batch_width").unwrap().as_u64().unwrap() >= 1);
    }
    assert!(seen.iter().all(|&s| s), "every request answered exactly once");
    c.send(&Json::obj(vec![("op", Json::s("shutdown"))]));
    assert_eq!(c.recv().get("shutting_down"), Some(&Json::Bool(true)));
    let report = server.join().unwrap();
    assert_eq!(report.get("completed").unwrap().as_u64(), Some(n));
    // The burst coalesced: strictly fewer batches than requests.
    assert!(report.get("batches").unwrap().as_u64().unwrap() < n);
    assert!(report.get("mean_batch_width").unwrap().as_f64().unwrap() > 1.0);
}

/// The deadline rule: a lone request whose window expires still
/// dispatches — as a width-1 batch — rather than waiting for company.
#[test]
fn lone_request_dispatches_as_width_1_on_window_expiry() {
    let (g, _) = uniform_random(200, 4, 3);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
    let (addr, server) = boot(
        &plan,
        ServeConfig {
            coalesce_window_us: 2_000,
            max_batch: 64,
            ..ServeConfig::default()
        },
    );
    let mut c = Client::connect(addr);
    c.send(&query(1, 5, &[]));
    let resp = c.recv();
    assert_eq!(resp.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(resp.get("batch_width").unwrap().as_u64(), Some(1));
    c.send(&Json::obj(vec![("op", Json::s("shutdown"))]));
    c.recv();
    server.join().unwrap();
}

/// Typed backpressure, deterministically: queue depth 1 and an hour-long
/// window mean the second concurrent request *must* be rejected with
/// `overloaded`, while the first is still answered on shutdown drain.
#[test]
fn overload_is_a_typed_rejection_and_drain_answers_the_queued() {
    let (g, _) = uniform_random(200, 4, 5);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
    let (addr, server) = boot(
        &plan,
        ServeConfig {
            coalesce_window_us: 3_600_000_000, // effectively forever
            max_batch: 64,
            queue_depth: 1,
            ..ServeConfig::default()
        },
    );
    let mut c = Client::connect(addr);
    // One connection's requests are admitted strictly in order by its
    // reader thread, so this sequence is deterministic: query 1 occupies
    // the depth-1 queue (its window never expires) before query 2 is
    // even parsed. The interleaved stats op proves it is queued, not
    // completed, and exercises the inline stats path.
    c.send(&query(1, 3, &[]));
    c.send(&Json::obj(vec![("op", Json::s("stats"))]));
    let stats = c.recv();
    assert_eq!(stats.get("status").unwrap().as_str(), Some("ok"));
    let s = stats.get("stats").unwrap();
    assert_eq!(s.get("completed").unwrap().as_u64(), Some(0));
    assert_eq!(s.get("rejected").unwrap().as_u64(), Some(0));
    c.send(&query(2, 4, &[]));
    let resp = c.recv();
    assert_eq!(resp.get("status").unwrap().as_str(), Some("overloaded"));
    assert_eq!(resp.get("id").unwrap().as_u64(), Some(2));
    // Shutdown drains the queue: the first query is answered, not lost.
    c.send(&Json::obj(vec![("op", Json::s("shutdown"))]));
    let mut statuses = Vec::new();
    for _ in 0..2 {
        let r = c.recv();
        if r.get("shutting_down").is_some() {
            statuses.push("shutdown".to_string());
        } else {
            assert_eq!(r.get("status").unwrap().as_str(), Some("ok"));
            assert_eq!(r.get("id").unwrap().as_u64(), Some(1));
            statuses.push("ok".to_string());
        }
    }
    assert!(statuses.contains(&"ok".to_string()), "drained query must be answered");
    let report = server.join().unwrap();
    assert_eq!(report.get("completed").unwrap().as_u64(), Some(1));
    assert_eq!(report.get("rejected").unwrap().as_u64(), Some(1));
}

/// A request carrying a short deadline times out in the queue (window
/// far longer than the deadline) with a typed `timeout` response.
#[test]
fn queued_request_past_its_deadline_times_out() {
    let (g, _) = uniform_random(200, 4, 6);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
    let (addr, server) = boot(
        &plan,
        ServeConfig {
            coalesce_window_us: 3_600_000_000,
            max_batch: 64,
            ..ServeConfig::default()
        },
    );
    let mut c = Client::connect(addr);
    c.send(&Json::obj(vec![
        ("op", Json::s("query")),
        ("id", Json::u(9)),
        ("root", Json::u(3)),
        ("timeout_us", Json::u(5_000)),
    ]));
    let resp = c.recv();
    assert_eq!(resp.get("status").unwrap().as_str(), Some("timeout"));
    assert_eq!(resp.get("id").unwrap().as_u64(), Some(9));
    c.send(&Json::obj(vec![("op", Json::s("shutdown"))]));
    c.recv();
    let report = server.join().unwrap();
    assert_eq!(report.get("timed_out").unwrap().as_u64(), Some(1));
}

/// Admission-time validation: a bad root (or target) is answered
/// `bad_request` immediately and can never poison a coalesced batch;
/// malformed lines likewise. Well-formed traffic on the same connection
/// keeps working afterwards.
#[test]
fn bad_requests_rejected_at_admission_not_in_batch() {
    let (g, _) = uniform_random(100, 4, 8);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
    let (addr, server) = boot(
        &plan,
        ServeConfig { coalesce_window_us: 500, max_batch: 8, ..ServeConfig::default() },
    );
    let mut c = Client::connect(addr);
    // Root out of range: echoed back with the graph size.
    c.send(&query(1, 100, &[]));
    let resp = c.recv();
    assert_eq!(resp.get("status").unwrap().as_str(), Some("bad_request"));
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("100"));
    // Target out of range.
    c.send(&query(2, 0, &[1_000]));
    assert_eq!(c.recv().get("status").unwrap().as_str(), Some("bad_request"));
    // Malformed JSON.
    c.writer.write_all(b"this is not json\n").unwrap();
    assert_eq!(c.recv().get("status").unwrap().as_str(), Some("bad_request"));
    // Unknown op.
    c.send(&Json::obj(vec![("op", Json::s("frobnicate"))]));
    assert_eq!(c.recv().get("status").unwrap().as_str(), Some("bad_request"));
    // The connection still serves good queries — and the earlier bad
    // root did not fail this coalesced batch.
    c.send(&query(3, 7, &[]));
    let resp = c.recv();
    assert_eq!(resp.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(resp.get("id").unwrap().as_u64(), Some(3));
    c.send(&Json::obj(vec![("op", Json::s("shutdown"))]));
    c.recv();
    let report = server.join().unwrap();
    assert_eq!(report.get("bad_requests").unwrap().as_u64(), Some(4));
    assert_eq!(report.get("completed").unwrap().as_u64(), Some(1));
}

/// Over-wide serve configs fail at bind time with the width echoed back
/// — the serve-side face of the `for_lanes` clamp fix.
#[test]
fn over_wide_max_batch_fails_at_config_time_with_width_echoed() {
    let (g, _) = uniform_random(100, 4, 9);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
    for bad in [0usize, 513, 1024] {
        let err = Server::bind(
            Arc::clone(&plan),
            ServeConfig { max_batch: bad, ..ServeConfig::default() },
        )
        .unwrap_err();
        assert!(
            err.to_string().contains(&bad.to_string()),
            "error must echo the requested width: {err}"
        );
    }
    // And the library-level check itself.
    assert_eq!(BatchWidth::for_lanes(513), None);
    assert_eq!(BatchWidth::for_lanes(512), Some(BatchWidth::W512));
}
