//! Crash-consistency corpus: every persisted artifact is published via
//! `atomic_write` (write-tmp → fsync → atomic-rename), so a reader can
//! only ever observe a complete old file or a complete new file. This
//! suite drives the other half of that contract: if a torn file *did*
//! appear (a crash mid-write on a filesystem without atomic rename, a
//! partial copy), every loader rejects it with a typed error — no
//! panics, no OOM-sized allocations, and never a silently-wrong graph
//! or plan.

use std::path::PathBuf;
use std::sync::Arc;

use butterfly_bfs::coordinator::{EngineConfig, PlanError, TraversalPlan};
use butterfly_bfs::graph::csr::Csr;
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::graph::io::{read_binary, write_binary};
use butterfly_bfs::graph::store::{write_store, GraphStore, StoreWriteOptions};
use butterfly_bfs::net::TopologyModel;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bbfs-crash-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn graph() -> Csr {
    let (g, _) = uniform_random(120, 4, 5);
    g
}

/// Prefix lengths exercising every structural boundary of a file plus a
/// stride-sweep through its interior.
fn torn_prefixes(len: usize) -> Vec<usize> {
    let mut cuts = vec![0, 1, 7, 8, 15, 16, 23, 24];
    cuts.extend((0..len).step_by(((len / 64).max(7)) | 1));
    if len > 0 {
        cuts.push(len - 1);
    }
    cuts.retain(|&c| c < len);
    cuts.sort_unstable();
    cuts.dedup();
    cuts
}

/// Torn `.bbfs` v1 snapshots are rejected typed at every prefix length,
/// and trailing garbage after a complete snapshot is rejected too (the
/// exact-length check). The untorn file still round-trips bit-exactly.
#[test]
fn torn_snapshot_corpus_rejected_typed() {
    let g = graph();
    let path = scratch("snap.bbfs");
    write_binary(&g, &path).unwrap();
    let full = std::fs::read(&path).unwrap();
    assert_eq!(read_binary(&path).unwrap(), g, "untorn snapshot round-trips");

    let torn = scratch("snap-torn.bbfs");
    for cut in torn_prefixes(full.len()) {
        std::fs::write(&torn, &full[..cut]).unwrap();
        assert!(
            read_binary(&torn).is_err(),
            "torn snapshot prefix of {cut}/{} bytes must be rejected",
            full.len()
        );
    }
    // A torn *suffix* of a concatenated write (old file + partial new
    // one) fails the exact-length check just the same.
    let mut padded = full.clone();
    padded.extend_from_slice(&full[..9]);
    std::fs::write(&torn, &padded).unwrap();
    assert!(read_binary(&torn).is_err(), "trailing bytes must be rejected");
}

/// Torn `.bbfs` v2 store containers are rejected typed at every prefix
/// length — through both the file loader and the byte loader.
#[test]
fn torn_store_corpus_rejected_typed() {
    let g = graph();
    let path = scratch("store.bbfs");
    write_store(&g, &path, StoreWriteOptions::default()).unwrap();
    let full = std::fs::read(&path).unwrap();
    let decoded = GraphStore::open(&path).unwrap().to_csr().unwrap();
    assert_eq!(decoded, g, "untorn store round-trips");

    let torn = scratch("store-torn.bbfs");
    for cut in torn_prefixes(full.len()) {
        std::fs::write(&torn, &full[..cut]).unwrap();
        assert!(
            GraphStore::open(&torn).is_err(),
            "torn store prefix of {cut}/{} bytes must be rejected",
            full.len()
        );
        assert!(
            GraphStore::open_bytes(full[..cut].to_vec()).is_err(),
            "torn store bytes ({cut}) must be rejected"
        );
    }
}

/// Torn plan-cache files are rejected as [`PlanError::CacheCorrupt`] at
/// every prefix, the untorn cache warm-starts to bit-identical answers,
/// and a cache written under one interconnect is refused under another
/// with a typed fingerprint mismatch naming the `net` field — never
/// silently reused with stale pricing.
#[test]
fn torn_plan_cache_rejected_and_fingerprint_pins_net() {
    let g = graph();
    let store_path = scratch("cache-store.bbfs");
    write_store(&g, &store_path, StoreWriteOptions::default()).unwrap();
    let store = Arc::new(GraphStore::open(&store_path).unwrap());
    let cfg = EngineConfig::dgx2(4, 2);
    let cold = TraversalPlan::build_from_store(Arc::clone(&store), cfg.clone()).unwrap();
    let cache = scratch("plan.cache.json");
    cold.save_cache(&cache).unwrap();
    let full = std::fs::read(&cache).unwrap();

    // Untorn: warm answers == cold answers.
    let warm = TraversalPlan::load_cache(Arc::clone(&store), cfg.clone(), &cache).unwrap();
    let a = cold.session().run(3).unwrap();
    let b = warm.session().run(3).unwrap();
    assert_eq!(a.dist(), b.dist(), "warm-start must be bit-identical");

    let torn = scratch("plan-torn.cache.json");
    // `save_cache` appends a trailing newline; cut strictly inside the
    // JSON text proper so every prefix is genuinely unparseable.
    for cut in torn_prefixes(full.len() - 1) {
        std::fs::write(&torn, &full[..cut]).unwrap();
        match TraversalPlan::load_cache(Arc::clone(&store), cfg.clone(), &torn) {
            Err(PlanError::CacheCorrupt(_)) => {}
            other => panic!("torn cache prefix {cut}: expected CacheCorrupt, got {other:?}"),
        }
    }

    // Same cache, different interconnect: typed mismatch naming `net`.
    let tiered = EngineConfig {
        topology: Some(TopologyModel::dgx2_cluster(2)),
        ..cfg
    };
    match TraversalPlan::load_cache(Arc::clone(&store), tiered, &cache) {
        Err(PlanError::CacheFingerprintMismatch { field, .. }) => {
            assert_eq!(field, "net", "the disagreeing field is named");
        }
        other => panic!("expected CacheFingerprintMismatch, got {other:?}"),
    }
}

/// The publish step itself: a failed `atomic_write` (here: the
/// destination path runs *through* an existing file) leaves the previous
/// complete artifact untouched and readable, a successful re-write
/// replaces it completely, and no `.tmp.` staging residue survives
/// either way.
#[test]
fn failed_publish_preserves_previous_artifact() {
    let g_old = graph();
    let (g_new, _) = uniform_random(90, 3, 6);
    let path = scratch("replace.bbfs");
    write_binary(&g_old, &path).unwrap();

    // A write that cannot even stage must leave the old snapshot intact.
    let impossible = path.join("child.bbfs");
    assert!(write_binary(&g_new, &impossible).is_err());
    assert_eq!(read_binary(&path).unwrap(), g_old, "old artifact survives");

    // A successful write replaces the contents completely.
    write_binary(&g_new, &path).unwrap();
    assert_eq!(read_binary(&path).unwrap(), g_new, "new artifact replaces old");

    let residue: Vec<_> = std::fs::read_dir(path.parent().unwrap())
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
        .collect();
    assert!(residue.is_empty(), "staging residue left behind: {residue:?}");
}
