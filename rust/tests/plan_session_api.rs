//! Plan/session API surface tests: typed `PlanError`/`QueryError` values
//! on every invalid input (API and CLI paths — no panics, no
//! `process::exit` mid-query), pooled session reuse equivalence against
//! fresh sessions, and field-for-field metrics fidelity against the
//! legacy `ButterflyBfs` engine.

use butterfly_bfs::coordinator::{
    EngineConfig, PlanError, QueryError, TraversalPlan,
};
use butterfly_bfs::graph::csr::VertexId;
use butterfly_bfs::graph::gen::urand::uniform_random;
use std::io::Write;

// ---------- typed errors: API path ----------

#[test]
fn grid_too_large_is_a_typed_plan_error() {
    // The satellite fix: `EngineConfig::dgx2_2d` on a graph with fewer
    // vertices than grid columns (or rows) used to die inside the
    // partitioner; it now surfaces as `PlanError::GridTooLarge`.
    let (g, _) = uniform_random(3, 1, 1);
    let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(2, 4)).unwrap_err();
    assert_eq!(err, PlanError::GridTooLarge { rows: 2, cols: 4, num_vertices: 3 });
    let shown = err.to_string();
    assert!(shown.contains("2x4") && shown.contains("3 vertices"), "{shown}");
    // Row axis too: the error is symmetric in the axes.
    let err = TraversalPlan::build(&g, EngineConfig::dgx2_2d(7, 1)).unwrap_err();
    assert!(matches!(err, PlanError::GridTooLarge { rows: 7, cols: 1, .. }));
    // And the 1D analog.
    let err = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4)).unwrap_err();
    assert_eq!(err, PlanError::TooManyNodes { num_nodes: 16, num_vertices: 3 });
}

#[test]
fn query_errors_round_trip_as_std_errors() {
    let (g, _) = uniform_random(40, 4, 2);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(4, 1))
        .unwrap()
        .session();
    let err: Box<dyn std::error::Error> = Box::new(session.run(40).unwrap_err());
    assert!(err.to_string().contains("root 40 out of range"), "{err}");
    let err = session.run_batch(&[]).unwrap_err();
    assert_eq!(err, QueryError::EmptyBatch);
    // 65 roots are no longer an error: the lane mask widens with the
    // batch. The hard cap moved from >64 to >512 (WidthTooLarge).
    let wide: Vec<VertexId> = vec![0; 65];
    assert_eq!(session.run_batch(&wide).unwrap().num_roots(), 65);
    let too_wide: Vec<VertexId> = vec![0; 513];
    let err = session.run_batch(&too_wide).unwrap_err();
    assert_eq!(err, QueryError::WidthTooLarge { got: 513, max: 512 });
    assert!(err.to_string().contains("512-lane limit"), "{err}");
    // Duplicates are valid — only width and range are errors.
    let b = session.run_batch(&[1, 1, 2]).unwrap();
    assert_eq!(b.dist(0), b.dist(1));
}

// ---------- typed errors: CLI path ----------

/// Write a tiny 3-vertex edge list the CLI can load.
fn tiny_graph_file(tag: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("bbfs-api-{}-{tag}.txt", std::process::id()));
    let mut f = std::fs::File::create(&p).unwrap();
    writeln!(f, "0 1").unwrap();
    writeln!(f, "1 2").unwrap();
    p
}

#[test]
fn cli_reports_grid_too_large_cleanly() {
    let graph = tiny_graph_file("grid");
    let exe = env!("CARGO_BIN_EXE_butterfly-bfs");
    let out = std::process::Command::new(exe)
        .args([
            "run",
            "--graph",
            graph.to_str().unwrap(),
            "--nodes",
            "8",
            "--mode",
            "2d",
            "--grid",
            "2x4",
        ])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "typed error exits with code 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("error:") && stderr.contains("2x4"),
        "clean error line, got: {stderr}"
    );
    std::fs::remove_file(&graph).ok();
}

#[test]
fn cli_reports_root_out_of_range_cleanly() {
    let graph = tiny_graph_file("root");
    let exe = env!("CARGO_BIN_EXE_butterfly-bfs");
    let out = std::process::Command::new(exe)
        .args([
            "run",
            "--graph",
            graph.to_str().unwrap(),
            "--nodes",
            "2",
            "--root",
            "99",
        ])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("out of range"), "got: {stderr}");
    std::fs::remove_file(&graph).ok();
}

// ---------- session reuse ----------

/// The deterministic slice of a run's metrics.
fn metrics_key(m: &butterfly_bfs::coordinator::RunMetrics) -> (u64, u64, u64, usize) {
    (m.reached, m.messages(), m.bytes(), m.depth())
}

#[test]
fn session_reuse_matches_fresh_sessions() {
    let (g, _) = uniform_random(600, 8, 3);
    for cfg in [EngineConfig::dgx2(8, 4), EngineConfig::dgx2_2d(2, 4)] {
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        let mut reused = plan.session();
        for root in [0u32, 17, 401, 17] {
            let r = reused.run(root).unwrap();
            let fresh = plan.session().run(root).unwrap();
            assert_eq!(r.dist(), fresh.dist(), "root {root}");
            assert_eq!(metrics_key(r.metrics()), metrics_key(fresh.metrics()));
            // An explicit reset between queries changes nothing.
            reused.reset();
            let after_reset = reused.run(root).unwrap();
            assert_eq!(after_reset.dist(), fresh.dist());
        }
    }
}

#[test]
fn batch_after_single_root_and_width_changes_match_fresh() {
    let (g, _) = uniform_random(600, 8, 9);
    for cfg in [EngineConfig::dgx2(8, 4), EngineConfig::dgx2_2d(2, 4)] {
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        let mut reused = plan.session();
        // Interleave: single-root, then batches of shrinking and growing
        // widths — the pooled lane state resets (and resizes) in place.
        reused.run(5).unwrap();
        let widths: Vec<Vec<VertexId>> = vec![
            (0..48u32).map(|i| (i * 7) % 600).collect(),
            vec![3],
            (0..64u32).map(|i| (i * 11) % 600).collect(),
            // Crossing lane-word boundaries rebuilds the pooled states;
            // returning below rebuilds them back.
            (0..130u32).map(|i| (i * 13) % 600).collect(),
            (0..300u32).map(|i| (i * 3) % 600).collect(),
            vec![7, 7],
        ];
        for roots in &widths {
            let b = reused.run_batch(roots).unwrap();
            reused.assert_batch_agreement().unwrap();
            let fresh = plan.session().run_batch(roots).unwrap();
            assert_eq!(b.num_roots(), fresh.num_roots());
            for lane in 0..b.num_roots() {
                assert_eq!(b.dist(lane), fresh.dist(lane), "lane {lane}");
            }
            assert_eq!(b.metrics().bytes(), fresh.metrics().bytes());
            assert_eq!(b.metrics().sync_rounds, fresh.metrics().sync_rounds);
            assert_eq!(b.reached_pairs(), fresh.reached_pairs());
        }
        // And a single-root query after all that batching is untouched.
        let r = reused.run(5).unwrap();
        let fresh = plan.session().run(5).unwrap();
        assert_eq!(r.dist(), fresh.dist());
    }
}

// ---------- legacy-shim fidelity ----------

#[allow(deprecated)]
#[test]
fn traversal_result_metrics_match_legacy_runmetrics_json() {
    use butterfly_bfs::coordinator::ButterflyBfs;
    let (g, _) = uniform_random(400, 6, 13);
    for cfg in [EngineConfig::dgx2(4, 2), EngineConfig::dgx2_2d(2, 2)] {
        let mut legacy = ButterflyBfs::new(&g, cfg.clone());
        let mut lm = legacy.run(7);
        let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
        let mut nm = session.run(7).unwrap().into_metrics();
        // Wallclock is measured per process run; everything else —
        // reach, depth, per-level counts, bytes, simulated clock, the
        // fold/expand split — must match field for field in the JSON.
        lm.wall_seconds = 0.0;
        nm.wall_seconds = 0.0;
        assert_eq!(lm.to_json().render(), nm.to_json().render());
    }
}

#[allow(deprecated)]
#[test]
fn batch_result_metrics_match_legacy_batchmetrics_json() {
    use butterfly_bfs::coordinator::ButterflyBfs;
    let (g, _) = uniform_random(400, 6, 21);
    let roots: Vec<VertexId> = (0..32u32).map(|i| (i * 9) % 400).collect();
    for cfg in [EngineConfig::dgx2(8, 4), EngineConfig::dgx2_2d(2, 4)] {
        let mut legacy = ButterflyBfs::new(&g, cfg.clone());
        let mut lm = legacy.run_batch(&roots);
        let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
        let mut nm = session.run_batch(&roots).unwrap().into_metrics();
        lm.wall_seconds = 0.0;
        nm.wall_seconds = 0.0;
        assert_eq!(lm.to_json().render(), nm.to_json().render());
    }
}
