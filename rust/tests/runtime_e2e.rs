//! Three-layer integration: the AOT artifacts (JAX/Pallas → HLO text)
//! executed through PJRT inside the distributed engine, checked against
//! the native backend and the serial oracle. Skips (with a notice) when
//! `make artifacts` has not run. The whole file is gated on the `xla`
//! feature (the PJRT runtime needs the offline `xla` crate).
#![cfg(feature = "xla")]

use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::{EngineConfig, PatternKind, TraversalPlan};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::graph::gen::structured::{binary_tree, grid2d, star};
use butterfly_bfs::partition::one_d::partition_1d;
use butterfly_bfs::runtime::{find_artifact, variant_for, FrontierStep, XlaFrontierBackend};
use std::sync::Arc;

fn load_step(v: usize) -> Option<Arc<FrontierStep>> {
    let key = variant_for(v)?;
    let path = find_artifact(key)?;
    Some(Arc::new(FrontierStep::load(&path, key.num_vertices).expect("artifact compiles")))
}

macro_rules! require_artifacts {
    ($v:expr) => {
        match load_step($v) {
            Some(s) => s,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn xla_engine_structured_graphs() {
    let step = require_artifacts!(1024);
    for (name, g) in [
        ("star", star(900)),
        ("grid", grid2d(30, 30)),
        ("tree", binary_tree(1023)),
    ] {
        let cfg = EngineConfig::dgx2(4, 2);
        let part = partition_1d(&g, cfg.num_nodes);
        let backends = XlaFrontierBackend::for_slabs(Arc::clone(&step), &part.slabs(&g)).unwrap();
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        let mut session = plan.session_with_backends(backends).unwrap();
        let r = session.run(0).unwrap();
        session.assert_agreement().unwrap();
        assert_eq!(r.dist(), &serial_bfs(&g, 0)[..], "{name}");
    }
}

#[test]
fn xla_engine_kron_all_patterns() {
    let step = require_artifacts!(2048);
    let (g, _) = kronecker(KroneckerParams::graph500(11, 8), 3);
    for pattern in [
        PatternKind::Butterfly { fanout: 1 },
        PatternKind::Butterfly { fanout: 4 },
        PatternKind::AllToAllIterative,
    ] {
        let cfg = EngineConfig { pattern, ..EngineConfig::dgx2(6, 1) };
        let part = partition_1d(&g, cfg.num_nodes);
        let backends = XlaFrontierBackend::for_slabs(Arc::clone(&step), &part.slabs(&g)).unwrap();
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        let mut session = plan.session_with_backends(backends).unwrap();
        let r = session.run(5).unwrap();
        session.assert_agreement().unwrap();
        assert_eq!(r.dist(), &serial_bfs(&g, 5)[..], "{pattern:?}");
    }
}

#[test]
fn xla_direction_optimizing_matches_serial() {
    use butterfly_bfs::coordinator::config::DirectionMode;
    let step = require_artifacts!(1024);
    let (g, _) = kronecker(KroneckerParams::graph500(9, 16), 21);
    let cfg = EngineConfig {
        direction: DirectionMode::diropt(),
        ..EngineConfig::dgx2(4, 4)
    };
    let part = partition_1d(&g, cfg.num_nodes);
    let backends = XlaFrontierBackend::for_slabs(step, &part.slabs(&g)).unwrap();
    let plan = TraversalPlan::build(&g, cfg).unwrap();
    let mut session = plan.session_with_backends(backends).unwrap();
    let r = session.run(0).unwrap();
    session.assert_agreement().unwrap();
    assert_eq!(r.dist(), &serial_bfs(&g, 0)[..]);
}

#[test]
fn xla_metrics_match_native_metrics() {
    let step = require_artifacts!(1024);
    let (g, _) = kronecker(KroneckerParams::graph500(9, 8), 8);
    let cfg = EngineConfig::dgx2(4, 4);
    let part = partition_1d(&g, cfg.num_nodes);
    let backends = XlaFrontierBackend::for_slabs(step, &part.slabs(&g)).unwrap();
    // One plan, two sessions with different backends — the split API's
    // way of running backend comparisons over identical artifacts.
    let plan = TraversalPlan::build(&g, cfg).unwrap();
    let mut xla = plan.session_with_backends(backends).unwrap();
    let mut native = plan.session();
    let rx = xla.run(1).unwrap();
    let rn = native.run(1).unwrap();
    let (mx, mn) = (rx.metrics(), rn.metrics());
    // Same traversal structure: depth, reach, per-level discoveries, and
    // examined-edge counts all coincide.
    assert_eq!(mx.depth(), mn.depth());
    assert_eq!(mx.reached, mn.reached);
    assert_eq!(mx.edges_examined(), mn.edges_examined());
    for (lx, ln) in mx.levels.iter().zip(&mn.levels) {
        assert_eq!(lx.discovered, ln.discovered, "level {}", lx.level);
        assert_eq!(lx.frontier, ln.frontier, "level {}", lx.level);
    }
}

#[test]
fn all_artifact_sizes_load_and_run() {
    use butterfly_bfs::runtime::artifacts::{ArtifactKey, ARTIFACT_SIZES};
    for &v in ARTIFACT_SIZES {
        let Some(path) = find_artifact(ArtifactKey { num_vertices: v }) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let step = FrontierStep::load(&path, v).expect("compiles");
        // Tiny smoke: a 2-vertex path inside the padded space.
        use butterfly_bfs::graph::builder::GraphBuilder;
        let mut b = GraphBuilder::new(v.min(64));
        b.add_edge(0, 1);
        let (g, _) = b.build_undirected();
        let slab = g.row_slice(0, g.num_vertices() as u32);
        let adj = step.adjacency_literal(&slab).unwrap();
        let mut f = vec![0f32; v];
        f[0] = 1.0;
        let mut vis = vec![0f32; v];
        vis[0] = 1.0;
        let new = step.run(&adj, &f, &vis).unwrap();
        assert_eq!(new[1], 1.0, "v={v}");
        assert_eq!(new.iter().map(|&x| x as u32).sum::<u32>(), 1, "v={v}");
    }
}
