//! Fault-equivalence suite: the headline invariant of the fault layer.
//!
//! Under any injected [`FaultPlan`] that recovery tolerates, distances
//! are **bit-identical** to the fault-free run — across every partition
//! mode (1D butterfly, 2D fold+expand, hierarchical), every direction
//! policy, and batch widths spanning the full 512-lane envelope.
//! Tolerated faults only ever move the recovery counters (`retries`,
//! `retry_bytes`, `recovery_time`) and the simulated clock; the Phase-1
//! byte/message accounting and every lane's answer stay untouched.
//!
//! On top of the property, the edge cases the recovery ladder must pin:
//! faults at the first and the last byte-shipping level, several faults
//! in one round, a fault striking a bottom-up dense exchange, retry-budget
//! exhaustion (typed [`QueryError::Unrecoverable`], never a wrong
//! answer), kill-rank degrade + replay in all three modes, and the serve
//! layer's transparent retry surfacing `degraded: true` in `stats`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::config::DirectionMode;
use butterfly_bfs::coordinator::{
    BatchMetrics, EngineConfig, QueryError, TraversalPlan,
};
use butterfly_bfs::fault::{
    FaultInjector, FaultKind, FaultPlan, FaultSpec, FaultTolerantRunner,
};
use butterfly_bfs::graph::csr::{Csr, VertexId};
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::serve::{ServeConfig, Server};
use butterfly_bfs::util::json::Json;

const N: usize = 600;

fn graph() -> Csr {
    let (g, _) = uniform_random(N, 3, 11);
    g
}

fn modes() -> [(&'static str, EngineConfig); 3] {
    [
        ("1d", EngineConfig::dgx2(4, 2)),
        ("2d", EngineConfig::dgx2_2d(2, 2)),
        ("hier", EngineConfig::dgx2_cluster_hier(2, 2, 2)),
    ]
}

const DIRECTIONS: [DirectionMode; 3] = [
    DirectionMode::TopDown,
    DirectionMode::BottomUp,
    DirectionMode::DirOpt { alpha: 15, beta: 18 },
];

fn roots_of(width: usize) -> Vec<VertexId> {
    (0..width).map(|i| ((i * 37) % N) as VertexId).collect()
}

/// A drop fault on *every* transfer of *every* round at one level — the
/// blanket guarantees at least one spec addresses a transfer that
/// actually ships bytes, so the injection demonstrably fires.
fn blanket(plan: &TraversalPlan, level: u32, repeat: u32) -> FaultPlan {
    let mut faults = Vec::new();
    for (round, transfers) in plan.schedule().rounds.iter().enumerate() {
        for t in transfers {
            faults.push(FaultSpec {
                level,
                round,
                src: t.src,
                dst: t.dst,
                kind: FaultKind::Drop { repeat },
                max_fires: 0,
            });
        }
    }
    FaultPlan { faults, ..FaultPlan::default() }
}

/// Levels that shipped at least one exchange byte in a fault-free run —
/// the levels where an injected transfer fault can actually strike.
fn shipping_levels(m: &BatchMetrics) -> Vec<u32> {
    m.levels.iter().filter(|l| l.bytes > 0).map(|l| l.level).collect()
}

fn assert_counter_only(
    tag: &str,
    plan: &TraversalPlan,
    roots: &[VertexId],
    fplan: FaultPlan,
) -> (u64, u64) {
    let free = plan.session().run_batch(roots).unwrap();
    let injector = Arc::new(FaultInjector::new(fplan));
    let mut armed = plan.session();
    armed.arm_faults(Some(Arc::clone(&injector)));
    let faulted = armed.run_batch(roots).unwrap();

    for lane in 0..roots.len() {
        assert_eq!(free.dist(lane), faulted.dist(lane), "{tag} lane {lane}");
    }
    let (mf, ma) = (free.metrics(), faulted.metrics());
    assert_eq!(mf.levels.len(), ma.levels.len(), "{tag}: level count");
    for (a, b) in mf.levels.iter().zip(&ma.levels) {
        assert_eq!(a.bytes, b.bytes, "{tag} level {}: bytes", a.level);
        assert_eq!(a.messages, b.messages, "{tag} level {}: messages", a.level);
        assert_eq!(a.frontier, b.frontier, "{tag} level {}: frontier", a.level);
    }
    assert_eq!(mf.retries(), 0, "{tag}: fault-free run must not retry");
    let matched = injector.specs_matched();
    if matched > 0 {
        assert!(
            ma.recovery_time() > 0.0,
            "{tag}: {matched} specs fired but recovery_time is zero"
        );
        assert!(
            (ma.sim_seconds_with_recovery() - ma.sim_seconds() - ma.recovery_time()).abs()
                < 1e-12,
            "{tag}: with-recovery clock must be sim + recovery"
        );
    } else {
        assert_eq!(ma.retries(), 0, "{tag}: nothing fired, nothing retried");
        assert_eq!(ma.recovery_time(), 0.0, "{tag}: nothing fired, no recovery");
    }
    (matched as u64, ma.retries())
}

// ---------- the headline property ----------

/// Tolerated seeded fault plans are counter-only on every mode ×
/// direction × width combination, widths sweeping the full lane
/// envelope {1, 64, 256, 512}. Suite-wide, the generated schedules must
/// actually fire (retries > 0 somewhere) — otherwise the property would
/// pass vacuously.
#[test]
fn generated_fault_plans_are_counter_only_everywhere() {
    let g = graph();
    let mut total_matched = 0u64;
    let mut total_retries = 0u64;
    for (mi, (mode, base)) in modes().into_iter().enumerate() {
        for (di, direction) in DIRECTIONS.into_iter().enumerate() {
            let cfg = EngineConfig { direction, ..base.clone() };
            let plan = TraversalPlan::build(&g, cfg).unwrap();
            for width in [1usize, 64, 256, 512] {
                let roots = roots_of(width);
                let probe = plan.session().run_batch(&roots).unwrap();
                let seed = 0xF00D ^ ((mi as u64) << 16) ^ ((di as u64) << 8) ^ width as u64;
                let fplan = FaultPlan::generate(
                    seed,
                    8,
                    probe.metrics().levels.len() as u32,
                    plan.schedule().rounds.len(),
                    plan.schedule().num_nodes,
                );
                let tag = format!("{mode}/{direction:?}/w{width}");
                let (m, r) = assert_counter_only(&tag, &plan, &roots, fplan);
                total_matched += m;
                total_retries += r;
            }
        }
    }
    assert!(total_matched > 0, "no generated fault ever matched a live transfer");
    assert!(total_retries > 0, "no generated drop/corrupt ever forced a retry");
}

// ---------- edge cases ----------

/// Faults at the *first* and the *last* byte-shipping level are both
/// absorbed: the boundary levels exercise the seam right after the root
/// exchange and right before the traversal drains.
#[test]
fn faults_at_first_and_last_shipping_level_are_absorbed() {
    let g = graph();
    for (mode, base) in modes() {
        let plan = TraversalPlan::build(&g, base).unwrap();
        let roots = roots_of(5);
        let free = plan.session().run_batch(&roots).unwrap();
        let levels = shipping_levels(free.metrics());
        let (first, last) =
            (*levels.first().expect("bytes flow"), *levels.last().expect("bytes flow"));
        for level in [first, last] {
            let (matched, retries) = assert_counter_only(
                &format!("{mode}/level{level}"),
                &plan,
                &roots,
                blanket(&plan, level, 1),
            );
            assert!(matched >= 1, "{mode}: blanket at level {level} never fired");
            assert_eq!(retries, matched, "{mode}: one retry per matched drop");
        }
    }
}

/// Several faults striking the same round are each detected and each
/// priced: one retry per matched single-drop spec, no coalescing and no
/// double-counting.
#[test]
fn multiple_faults_in_one_round_each_priced() {
    let g = graph();
    let plan = TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap();
    let roots = roots_of(64);
    let free = plan.session().run_batch(&roots).unwrap();
    let busiest = free
        .metrics()
        .levels
        .iter()
        .max_by_key(|l| l.bytes)
        .expect("nonempty run")
        .level;
    let fplan = blanket(&plan, busiest, 1);
    let (matched, retries) =
        assert_counter_only("two-per-round", &plan, &roots, fplan);
    // The busiest level of a 4-rank butterfly ships on several transfers
    // per round — at least two specs must have fired in the same round.
    assert!(matched >= 2, "expected >= 2 fired specs, got {matched}");
    assert_eq!(retries, matched);
}

/// A fault striking a bottom-up dense exchange (the aggregated
/// whole-range transfer, not a sparse delta) is detected and retried the
/// same way — direction is invisible to the fault seam.
#[test]
fn bottom_up_dense_transfer_fault_is_absorbed() {
    let g = graph();
    let cfg = EngineConfig {
        direction: DirectionMode::BottomUp,
        ..EngineConfig::dgx2(4, 2)
    };
    let plan = TraversalPlan::build(&g, cfg).unwrap();
    let roots = roots_of(64);
    let free = plan.session().run_batch(&roots).unwrap();
    let dense = free
        .metrics()
        .levels
        .iter()
        .filter(|l| l.bottom_up && l.bytes > 0)
        .max_by_key(|l| l.bytes)
        .expect("bottom-up run ships dense frames")
        .level;
    let (matched, retries) = assert_counter_only(
        "bottom-up-dense",
        &plan,
        &roots,
        blanket(&plan, dense, 1),
    );
    assert!(matched >= 1, "dense-level blanket never fired");
    assert!(retries >= 1);
    // The answers also match the serial oracle, not just each other.
    let check = plan.session().run_batch(&roots).unwrap();
    for (lane, &r) in roots.iter().enumerate() {
        assert_eq!(check.dist(lane), &serial_bfs(&g, r)[..], "lane {lane}");
    }
}

/// A drop streak longer than the retry budget aborts with the typed
/// [`QueryError::Unrecoverable`] — attempts pinned at the budget — and
/// never returns distances at all, let alone wrong ones.
#[test]
fn exhausted_retry_budget_is_typed_never_a_wrong_answer() {
    let g = graph();
    for (mode, base) in modes() {
        let plan = TraversalPlan::build(&g, base).unwrap();
        let roots = roots_of(8);
        let free = plan.session().run_batch(&roots).unwrap();
        let busiest = free
            .metrics()
            .levels
            .iter()
            .max_by_key(|l| l.bytes)
            .expect("nonempty run")
            .level;
        let fplan = blanket(&plan, busiest, FaultPlan::default().max_retries + 1);
        let budget = fplan.max_retries;
        let mut armed = plan.session();
        armed.arm_faults(Some(Arc::new(FaultInjector::new(fplan))));
        match armed.run_batch(&roots) {
            Err(QueryError::Unrecoverable { attempts, .. }) => {
                assert_eq!(attempts, budget, "{mode}: attempts == retry budget");
            }
            other => panic!("{mode}: expected Unrecoverable, got {other:?}"),
        }
        // The session is reusable after the typed failure: disarm and the
        // next query answers correctly.
        armed.arm_faults(None);
        let again = armed.run_batch(&roots).unwrap();
        for lane in 0..roots.len() {
            assert_eq!(again.dist(lane), free.dist(lane), "{mode} lane {lane}");
        }
    }
}

/// Kill-rank recovery in all three partition modes: the runner degrades
/// onto the survivors, replays the lost level from the checkpoint, and
/// the final distances equal the serial oracle lane for lane.
#[test]
fn killed_rank_recovers_bit_identical_in_every_mode() {
    let g = graph();
    let roots: Vec<VertexId> = vec![0, 17, 300];
    for (mode, base) in modes() {
        let ranks = TraversalPlan::build(&g, base.clone())
            .unwrap()
            .schedule()
            .num_nodes;
        let kill = FaultPlan {
            faults: vec![FaultSpec {
                level: 1,
                round: 0,
                src: ranks - 1,
                dst: 0,
                kind: FaultKind::KillRank,
                max_fires: 1,
            }],
            ..FaultPlan::default()
        };
        let mut runner = FaultTolerantRunner::from_graph(&g, base, kill).unwrap();
        let got = runner.run_batch(&roots).unwrap();
        assert!(runner.is_degraded(), "{mode}: kill must force a re-plan");
        assert!(
            runner.active_plan().config().num_nodes < ranks as usize,
            "{mode}: degraded plan must use fewer ranks"
        );
        for (lane, &r) in roots.iter().enumerate() {
            assert_eq!(got.dist(lane), &serial_bfs(&g, r)[..], "{mode} lane {lane}");
        }
    }
}

// ---------- serve-layer degradation over a real socket ----------

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    line: String,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client {
            writer: stream.try_clone().unwrap(),
            reader: BufReader::new(stream),
            line: String::new(),
        }
    }

    fn roundtrip(&mut self, req: &Json) -> Json {
        self.writer.write_all(req.render().as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.line.clear();
        let n = self.reader.read_line(&mut self.line).unwrap();
        assert!(n > 0, "server closed the connection");
        Json::parse(self.line.trim()).unwrap()
    }
}

/// A transient kill on the first served batch is invisible to the
/// client beyond latency: the server's one transparent retry answers
/// correctly, and `stats` reports `retried >= 1`, `health: degraded`,
/// `degraded: true`.
#[test]
fn serve_retries_transparently_and_reports_degraded() {
    let (g, _) = uniform_random(400, 5, 7);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(4, 2)).unwrap());
    let kill = FaultPlan {
        faults: vec![FaultSpec {
            level: 1,
            round: 0,
            src: 2,
            dst: 0,
            kind: FaultKind::KillRank,
            max_fires: 1,
        }],
        ..FaultPlan::default()
    };
    let mut server = Server::bind(
        Arc::clone(&plan),
        ServeConfig { coalesce_window_us: 1_000, ..ServeConfig::default() },
    )
    .unwrap();
    server.arm_faults(Arc::new(FaultInjector::new(kill)));
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let mut c = Client::connect(addr);
    let root: u64 = 42;
    let resp = c.roundtrip(&Json::obj(vec![
        ("op", Json::s("query")),
        ("id", Json::u(1)),
        ("root", Json::u(root)),
        ("targets", Json::Arr(vec![Json::u(0), Json::u(399)])),
    ]));
    assert_eq!(
        resp.get("status").unwrap().as_str(),
        Some("ok"),
        "transient fault must be retried, not surfaced: {resp:?}"
    );
    let solo = plan.session().run(root as VertexId).unwrap();
    let dist = resp.get("dist").unwrap().as_arr().unwrap();
    for (t, d) in [0usize, 399].into_iter().zip(dist) {
        match d.as_u64() {
            Some(served) => assert_eq!(served, u64::from(solo.dist()[t]), "target {t}"),
            None => assert_eq!(solo.dist()[t], u32::MAX, "target {t}"),
        }
    }

    let stats = c.roundtrip(&Json::obj(vec![("op", Json::s("stats"))]));
    assert_eq!(stats.get("status").unwrap().as_str(), Some("ok"));
    let s = stats.get("stats").unwrap();
    assert!(
        s.get("retried").unwrap().as_u64().unwrap() >= 1,
        "retry must be recorded: {s:?}"
    );
    assert_eq!(s.get("health").unwrap().as_str(), Some("degraded"));
    assert_eq!(s.get("degraded"), Some(&Json::Bool(true)));

    let bye = c.roundtrip(&Json::obj(vec![("op", Json::s("shutdown"))]));
    assert_eq!(bye.get("shutting_down"), Some(&Json::Bool(true)));
    let report = handle.join().unwrap();
    assert_eq!(report.get("completed").unwrap().as_u64(), Some(1));
}
