//! Serve-mode cancellation and relabeled-store serving: a client that
//! hangs up while its query is still coalescing must be dropped into the
//! `cancelled` metric (no batch lane, no write to a dead socket), and a
//! plan built from a degree-sorted `.bbfs` store must keep speaking the
//! client's original vertex ids over the wire.

use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::graph::store::{encode_store, GraphStore, StoreWriteOptions};
use butterfly_bfs::serve::{ServeConfig, Server};
use butterfly_bfs::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    line: String,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client {
            writer: stream.try_clone().unwrap(),
            reader: BufReader::new(stream),
            line: String::new(),
        }
    }

    fn send(&mut self, req: &Json) {
        self.writer.write_all(req.render().as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
    }

    fn recv(&mut self) -> Json {
        self.line.clear();
        let n = self.reader.read_line(&mut self.line).unwrap();
        assert!(n > 0, "server closed the connection");
        Json::parse(self.line.trim()).unwrap()
    }
}

fn query(id: u64, root: u64, targets: &[u64]) -> Json {
    let mut fields = vec![
        ("op", Json::s("query")),
        ("id", Json::u(id)),
        ("root", Json::u(root)),
    ];
    if !targets.is_empty() {
        fields.push(("targets", Json::Arr(targets.iter().map(|&t| Json::u(t)).collect())));
    }
    Json::obj(fields)
}

fn boot(
    plan: &Arc<TraversalPlan>,
    cfg: ServeConfig,
) -> (std::net::SocketAddr, std::thread::JoinHandle<Json>) {
    let server = Server::bind(Arc::clone(plan), cfg).unwrap();
    let addr = server.local_addr().unwrap();
    (addr, std::thread::spawn(move || server.run().unwrap()))
}

/// Client A queues a query into a long coalescing window and then drops
/// its socket. The dispatcher must detect the dead connection at
/// dispatch time, skip the query (it gets no lane), and count it in
/// `cancelled` — while client B's traffic on the same server keeps
/// working normally.
#[test]
fn dropped_connection_cancels_queued_query_at_dispatch() {
    let (g, _) = uniform_random(200, 4, 11);
    let plan = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
    let (addr, server) = boot(
        &plan,
        ServeConfig {
            coalesce_window_us: 300_000, // long enough to hang up inside
            max_batch: 64,
            workers: 1,
            ..ServeConfig::default()
        },
    );
    {
        // Client A: queue a query, then vanish without reading anything.
        let mut a = Client::connect(addr);
        a.send(&query(1, 5, &[]));
        // Dropping both halves closes the socket; the server's reader
        // sees EOF while the query is still waiting out its window.
    }
    // Client B polls live stats until the dispatcher has observed the
    // hang-up (bounded: 5 s worst case, far beyond the 300 ms window).
    let mut b = Client::connect(addr);
    let mut cancelled = 0;
    for _ in 0..100 {
        b.send(&Json::obj(vec![("op", Json::s("stats"))]));
        let stats = b.recv();
        assert_eq!(stats.get("status").unwrap().as_str(), Some("ok"));
        cancelled = stats
            .get("stats")
            .unwrap()
            .get("cancelled")
            .unwrap()
            .as_u64()
            .unwrap();
        if cancelled >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(cancelled, 1, "dropped client's query must be counted as cancelled");
    // The server is still healthy: B's own query is answered.
    b.send(&query(7, 3, &[]));
    let resp = b.recv();
    assert_eq!(resp.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(resp.get("id").unwrap().as_u64(), Some(7));
    b.send(&Json::obj(vec![("op", Json::s("shutdown"))]));
    b.recv();
    let report = server.join().unwrap();
    assert_eq!(report.get("cancelled").unwrap().as_u64(), Some(1));
    // Only B's query ran; A's never consumed a lane.
    assert_eq!(report.get("completed").unwrap().as_u64(), Some(1));
}

/// Serving from a degree-sorted (relabeled) store plan: clients keep
/// speaking original vertex ids. Responses echo the original ids and the
/// distances match an in-memory plan over the unrelabeled graph.
#[test]
fn relabeled_store_plan_serves_original_id_answers() {
    let (g, _) = uniform_random(300, 5, 13);
    let reference = Arc::new(TraversalPlan::build(&g, EngineConfig::dgx2(2, 1)).unwrap());
    let encoded = encode_store(
        &g,
        StoreWriteOptions { relabel: true, ..StoreWriteOptions::default() },
    )
    .unwrap();
    let store = Arc::new(GraphStore::open_bytes(encoded.bytes).unwrap());
    assert!(store.is_relabeled());
    let plan =
        TraversalPlan::build_from_store(Arc::clone(&store), EngineConfig::dgx2(2, 1)).unwrap();
    plan.materialize().unwrap();
    let plan = Arc::new(plan);
    let (addr, server) = boot(
        &plan,
        ServeConfig { coalesce_window_us: 500, max_batch: 8, ..ServeConfig::default() },
    );
    let mut c = Client::connect(addr);
    let targets: Vec<u64> = vec![0, 42, 299];
    for (id, root) in [(1u64, 9u64), (2, 131), (3, 250)] {
        c.send(&query(id, root, &targets));
        let resp = c.recv();
        assert_eq!(resp.get("status").unwrap().as_str(), Some("ok"), "id {id}");
        // The response speaks the client's id space, not the store's.
        assert_eq!(resp.get("root").unwrap().as_u64(), Some(root));
        let echoed: Vec<u64> = resp
            .get("targets")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_u64().unwrap())
            .collect();
        assert_eq!(echoed, targets);
        let solo = reference.session().run(root as u32).unwrap();
        let dist = resp.get("dist").unwrap().as_arr().unwrap();
        for (t, d) in targets.iter().zip(dist) {
            let expect = solo.dist()[*t as usize];
            match d.as_u64() {
                Some(served) => {
                    assert_eq!(served, expect as u64, "root {root} target {t}")
                }
                None => assert_eq!(expect, u32::MAX, "root {root} target {t}"),
            }
        }
    }
    c.send(&Json::obj(vec![("op", Json::s("shutdown"))]));
    c.recv();
    server.join().unwrap();
}
