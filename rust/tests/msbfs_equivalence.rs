//! MS-BFS coverage across the analog suite: `run_batch` distances equal
//! independent `serial_bfs` runs on every `table1_suite()` graph at tiny
//! scale — including batches smaller than 64 and duplicate roots — plus
//! the batched-vs-sequential amortization acceptance check.

use butterfly_bfs::bfs::msbfs::{ms_bfs, sample_batch_roots};
use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::csr::VertexId;
use butterfly_bfs::graph::gen::table1_suite;

/// Every suite graph (tiny scale): an 8-lane batch with a duplicate root
/// appended matches per-root serial BFS and the single-node bit-parallel
/// oracle, on two engine shapes.
#[test]
fn suite_run_batch_equals_serial() {
    for spec in table1_suite() {
        let g = spec.generate_scaled(-7);
        let mut roots = sample_batch_roots(&g, 8, 0xACE0 ^ spec.seed);
        roots.push(roots[0]); // duplicate root rides along as its own lane
        let serial: Vec<Vec<u32>> =
            roots.iter().map(|&r| serial_bfs(&g, r)).collect();
        let oracle = ms_bfs(&g, &roots);
        for (nodes, fanout) in [(16usize, 1u32), (9, 4)] {
            let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(nodes, fanout))
                .unwrap()
                .session();
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap_or_else(|e| {
                panic!("{} n{nodes} f{fanout}: {e}", spec.name)
            });
            assert_eq!(b.num_roots(), roots.len());
            for (lane, want) in serial.iter().enumerate() {
                assert_eq!(
                    b.dist(lane),
                    &want[..],
                    "{} n{nodes} f{fanout} lane {lane}",
                    spec.name
                );
                assert_eq!(oracle.dist(lane), &want[..], "{} oracle", spec.name);
            }
        }
    }
}

/// A full-width 64-lane batch on the small-world suite member.
#[test]
fn full_width_batch_on_kron_like() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "kron-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    let roots = sample_batch_roots(&g, 64, 0x5EED);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4))
        .unwrap()
        .session();
    let b = session.run_batch(&roots).unwrap();
    session.assert_batch_agreement().unwrap();
    assert_eq!(b.num_roots(), 64);
    for (lane, &r) in roots.iter().enumerate() {
        assert_eq!(b.dist(lane), &serial_bfs(&g, r)[..], "lane {lane}");
    }
}

/// Batch widths 1, 2, and 63 behave identically to full width — the lane
/// mask never leaks into unused bits.
#[test]
fn partial_widths_match_serial() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "urand-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    // One session serves every width back to back — the pooled-reuse
    // path (lane state resets in place between batches).
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(8, 2))
        .unwrap()
        .session();
    for width in [1usize, 2, 63] {
        let roots = sample_batch_roots(&g, width, width as u64);
        let b = session.run_batch(&roots).unwrap();
        session.assert_batch_agreement().unwrap();
        for (lane, &r) in roots.iter().enumerate() {
            assert_eq!(
                b.dist(lane),
                &serial_bfs(&g, r)[..],
                "width {width} lane {lane}"
            );
        }
    }
}

/// The acceptance criterion on a suite graph: one 64-root batch ships
/// strictly fewer synchronization bytes and executes many-fold fewer
/// schedule rounds than the same 64 roots run sequentially.
#[test]
fn batch_amortizes_bytes_and_rounds_on_suite_graph() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "webbase-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    let roots: Vec<VertexId> = sample_batch_roots(&g, 64, 0xA11);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4))
        .unwrap()
        .session();
    let batch = session.run_batch(&roots).unwrap();
    session.assert_batch_agreement().unwrap();
    let bm = batch.metrics();
    let seq = session.sequential_baseline(&roots).unwrap();
    assert!(
        bm.bytes() < seq.bytes,
        "batch bytes {} !< sequential {}",
        bm.bytes(),
        seq.bytes
    );
    assert!(
        bm.sync_rounds * 8 < seq.sync_rounds,
        "batch rounds {} vs sequential {}",
        bm.sync_rounds,
        seq.sync_rounds
    );
    // The simulated clock agrees with the amortization story: the batch is
    // faster end-to-end than 64 back-to-back traversals.
    assert!(
        bm.sim_seconds() < seq.sim_seconds,
        "batch sim {} !< sequential {}",
        bm.sim_seconds(),
        seq.sim_seconds
    );
}
