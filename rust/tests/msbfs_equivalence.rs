//! MS-BFS coverage across the analog suite: `run_batch` distances equal
//! independent `serial_bfs` runs on every `table1_suite()` graph at tiny
//! scale — including batches smaller than 64, duplicate roots, and wide
//! batches at every lane word count (W ∈ {2, 4, 8}) — plus the
//! batched-vs-sequential amortization acceptance check and the
//! chunked-64 == one-wide-batch bit-identity property.

use butterfly_bfs::bfs::msbfs::{ms_bfs, sample_batch_roots};
use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::config::DirectionMode;
use butterfly_bfs::coordinator::{EngineConfig, KernelVariant, TraversalPlan};
use butterfly_bfs::graph::csr::VertexId;
use butterfly_bfs::graph::gen::structured::star;
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::util::propcheck::{forall, gen, Config};

/// Every suite graph (tiny scale): an 8-lane batch with a duplicate root
/// appended matches per-root serial BFS and the single-node bit-parallel
/// oracle, on two engine shapes.
#[test]
fn suite_run_batch_equals_serial() {
    for spec in table1_suite() {
        let g = spec.generate_scaled(-7);
        let mut roots = sample_batch_roots(&g, 8, 0xACE0 ^ spec.seed);
        roots.push(roots[0]); // duplicate root rides along as its own lane
        let serial: Vec<Vec<u32>> =
            roots.iter().map(|&r| serial_bfs(&g, r)).collect();
        let oracle = ms_bfs(&g, &roots);
        for (nodes, fanout) in [(16usize, 1u32), (9, 4)] {
            let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(nodes, fanout))
                .unwrap()
                .session();
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap_or_else(|e| {
                panic!("{} n{nodes} f{fanout}: {e}", spec.name)
            });
            assert_eq!(b.num_roots(), roots.len());
            for (lane, want) in serial.iter().enumerate() {
                assert_eq!(
                    b.dist(lane),
                    &want[..],
                    "{} n{nodes} f{fanout} lane {lane}",
                    spec.name
                );
                assert_eq!(oracle.dist(lane), &want[..], "{} oracle", spec.name);
            }
        }
    }
}

/// A full-width 64-lane batch on the small-world suite member.
#[test]
fn full_width_batch_on_kron_like() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "kron-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    let roots = sample_batch_roots(&g, 64, 0x5EED);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4))
        .unwrap()
        .session();
    let b = session.run_batch(&roots).unwrap();
    session.assert_batch_agreement().unwrap();
    assert_eq!(b.num_roots(), 64);
    for (lane, &r) in roots.iter().enumerate() {
        assert_eq!(b.dist(lane), &serial_bfs(&g, r)[..], "lane {lane}");
    }
}

/// Partial batch widths on both sides of every word boundary behave
/// identically to full width — the lane mask never leaks into unused
/// bits, and one session serves every width back to back (the pooled
/// lane state rebuilds on word-count changes, resets in place otherwise).
#[test]
fn partial_widths_match_serial() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "urand-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    // One session serves every width back to back — the pooled-reuse
    // path (lane state resets in place between batches).
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(8, 2))
        .unwrap()
        .session();
    for width in [1usize, 2, 63, 65, 127, 129, 257, 511] {
        let roots = sample_batch_roots(&g, width, width as u64);
        let b = session.run_batch(&roots).unwrap();
        session.assert_batch_agreement().unwrap();
        // Spot-check a handful of lanes per width (serial per root is the
        // cost driver at 511 lanes).
        for lane in [0, width / 2, width - 1] {
            assert_eq!(
                b.dist(lane),
                &serial_bfs(&g, roots[lane])[..],
                "width {width} lane {lane}"
            );
        }
        // Full-lane cross-check against the bit-parallel oracle.
        let oracle = ms_bfs(&g, &roots);
        for lane in 0..width {
            assert_eq!(b.dist(lane), oracle.dist(lane), "width {width}");
        }
    }
}

/// Wide batches at every word count: W ∈ {2, 4, 8} via widths 96 / 200 /
/// 300, duplicate-heavy and structured root sets, 1D and 2D, against the
/// bit-parallel oracle and serial spot checks.
#[test]
fn wide_batches_all_word_counts_match_oracle() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "urand-like")
        .unwrap();
    let g = spec.generate_scaled(-9);
    let n = g.num_vertices() as u32;
    for (width, want_words) in [(96usize, 2usize), (200, 4), (300, 8)] {
        // Structured + duplicate lanes: every fourth lane repeats root 0.
        let roots: Vec<VertexId> = (0..width)
            .map(|i| if i % 4 == 0 { 0 } else { (i as u32 * 13) % n })
            .collect();
        let oracle = ms_bfs(&g, &roots);
        for cfg in [EngineConfig::dgx2(8, 4), EngineConfig::dgx2_2d(2, 3)] {
            let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
            let b = session.run_batch(&roots).unwrap();
            session.assert_batch_agreement().unwrap();
            assert_eq!(b.metrics().lane_words, want_words, "width {width}");
            for lane in 0..width {
                assert_eq!(b.dist(lane), oracle.dist(lane), "w={width} lane={lane}");
            }
            // Duplicate lanes agree with each other and with serial.
            assert_eq!(b.dist(0), b.dist(4));
            assert_eq!(b.dist(0), &serial_bfs(&g, 0)[..]);
        }
    }
}

/// The chunked-execution identity: one wide batch is bit-identical, lane
/// for lane, to its 64-root chunks run through the same session — and
/// never runs more sync rounds than the chunks combined.
#[test]
fn property_chunked_64_equals_one_wide_batch() {
    forall(Config::cases(10), "chunked == wide batch", |rng| {
        let n = gen::usize_in(rng, 20, 250);
        let ef = gen::usize_in(rng, 1, 5) as u32;
        let width = gen::usize_in(rng, 65, 300);
        let (g, _) = uniform_random(n, ef, rng.next_u64());
        let roots: Vec<VertexId> =
            (0..width).map(|_| rng.next_usize(n) as VertexId).collect();
        let cfg = if rng.next_below(2) == 0 {
            EngineConfig::dgx2(gen::usize_in(rng, 1, 8.min(n)), 2)
        } else {
            let rows = gen::usize_in(rng, 1, 3.min(n)) as u32;
            let cols = gen::usize_in(rng, 1, 3.min(n)) as u32;
            EngineConfig::dgx2_2d(rows, cols)
        };
        let plan = TraversalPlan::build(&g, cfg).unwrap();
        let mut session = plan.session();
        let wide = session.run_batch(&roots).unwrap();
        let mut ok = session.assert_batch_agreement().is_ok();
        let mut chunk_rounds = 0;
        for (ci, chunk) in roots.chunks(64).enumerate() {
            let cb = session.run_batch(chunk).unwrap();
            ok &= cb.metrics().lane_words == 1;
            chunk_rounds += cb.metrics().sync_rounds;
            for (lane, _) in chunk.iter().enumerate() {
                ok &= cb.dist(lane) == wide.dist(ci * 64 + lane);
            }
        }
        ok &= wide.metrics().sync_rounds <= chunk_rounds;
        (ok, format!("n={n} ef={ef} width={width}"))
    });
}

/// The acceptance criterion on a suite graph: one 64-root batch ships
/// strictly fewer synchronization bytes and executes many-fold fewer
/// schedule rounds than the same 64 roots run sequentially.
#[test]
fn batch_amortizes_bytes_and_rounds_on_suite_graph() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "webbase-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    let roots: Vec<VertexId> = sample_batch_roots(&g, 64, 0xA11);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4))
        .unwrap()
        .session();
    let batch = session.run_batch(&roots).unwrap();
    session.assert_batch_agreement().unwrap();
    let bm = batch.metrics();
    let seq = session.sequential_baseline(&roots).unwrap();
    assert!(
        bm.bytes() < seq.bytes,
        "batch bytes {} !< sequential {}",
        bm.bytes(),
        seq.bytes
    );
    assert!(
        bm.sync_rounds * 8 < seq.sync_rounds,
        "batch rounds {} vs sequential {}",
        bm.sync_rounds,
        seq.sync_rounds
    );
    // The simulated clock agrees with the amortization story: the batch is
    // faster end-to-end than 64 back-to-back traversals.
    assert!(
        bm.sim_seconds() < seq.sim_seconds,
        "batch sim {} !< sequential {}",
        bm.sim_seconds(),
        seq.sim_seconds
    );
}

/// The tentpole identity: every mask-kernel variant (`scalar`, `chunked`,
/// and the `auto` resolver) produces bit-identical distances on random
/// graphs, at widths crossing every lane-word boundary, under all three
/// partition modes and all three direction policies. The scalar kernel
/// additionally never reports skipped words (it has no skip path).
#[test]
fn property_kernel_variants_bit_identical() {
    const WIDTHS: [usize; 11] =
        [63, 64, 65, 128, 129, 192, 256, 257, 320, 448, 512];
    forall(Config::cases(8), "kernel variants bit-identical", |rng| {
        let n = gen::usize_in(rng, 20, 200);
        let ef = gen::usize_in(rng, 1, 5) as u32;
        let width = WIDTHS[gen::usize_in(rng, 0, WIDTHS.len() - 1)];
        let (g, _) = uniform_random(n, ef, rng.next_u64());
        let roots: Vec<VertexId> =
            (0..width).map(|_| rng.next_usize(n) as VertexId).collect();
        let base = match gen::usize_in(rng, 0, 2) {
            0 => EngineConfig::dgx2(gen::usize_in(rng, 1, 8.min(n)), 2),
            1 => EngineConfig::dgx2_2d(2, 2),
            _ => EngineConfig::dgx2_cluster_hier(2, 2, 2),
        };
        let direction = match gen::usize_in(rng, 0, 2) {
            0 => DirectionMode::TopDown,
            1 => DirectionMode::BottomUp,
            _ => DirectionMode::diropt(),
        };
        let mut ok = true;
        let mut oracle: Option<Vec<Vec<u32>>> = None;
        for kernel in
            [KernelVariant::Auto, KernelVariant::Scalar, KernelVariant::Chunked]
        {
            let cfg = EngineConfig {
                direction,
                kernel,
                ..base.clone()
            };
            let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
            let b = session.run_batch(&roots).unwrap();
            ok &= session.assert_batch_agreement().is_ok();
            if kernel == KernelVariant::Scalar {
                ok &= b.metrics().words_skipped() == 0;
            }
            let dists: Vec<Vec<u32>> =
                (0..width).map(|lane| b.dist(lane).to_vec()).collect();
            match &oracle {
                None => oracle = Some(dists),
                Some(o) => ok &= o == &dists,
            }
        }
        (
            ok,
            format!(
                "n={n} ef={ef} width={width} dir={direction:?} \
                 mode={}",
                base.partition.name()
            ),
        )
    });
}

/// LRB-binned bottom-up composes with the chunked kernel bit-identically
/// to the flat candidate scan — on a uniform random graph and on a
/// degenerate star where every probe candidate lands in the top degree
/// bin. Binning only regroups the probe dispatches: the word traffic is
/// unchanged, and the largest single dispatch never grows.
#[test]
fn lrb_binned_bottom_up_equals_flat_scan() {
    let (urand, _) = uniform_random(300, 5, 42);
    let hub = star(257);
    for g in [&urand, &hub] {
        let roots = sample_batch_roots(g, 100, 0xB1B);
        let serial: Vec<Vec<u32>> =
            roots.iter().map(|&r| serial_bfs(g, r)).collect();
        for direction in [DirectionMode::BottomUp, DirectionMode::diropt()] {
            let mut binned: Option<(Vec<Vec<u32>>, u64, u64)> = None;
            for use_lrb in [true, false] {
                let cfg = EngineConfig {
                    direction,
                    use_lrb,
                    kernel: KernelVariant::Chunked,
                    ..EngineConfig::dgx2(4, 2)
                };
                let mut session =
                    TraversalPlan::build(g, cfg).unwrap().session();
                let b = session.run_batch(&roots).unwrap();
                session.assert_batch_agreement().unwrap();
                let dists: Vec<Vec<u32>> =
                    (0..roots.len()).map(|l| b.dist(l).to_vec()).collect();
                assert_eq!(dists, serial, "lrb={use_lrb} {direction:?}");
                let m = b.metrics();
                match &binned {
                    None => {
                        binned = Some((
                            dists,
                            m.words_touched(),
                            m.dispatch_max_work(),
                        ));
                    }
                    Some((want, words, max_work)) => {
                        assert_eq!(&dists, want, "{direction:?}");
                        assert_eq!(
                            m.words_touched(),
                            *words,
                            "binning must not change word traffic ({direction:?})"
                        );
                        assert!(
                            *max_work <= m.dispatch_max_work(),
                            "LRB max dispatch {} > flat {} ({direction:?})",
                            max_work,
                            m.dispatch_max_work(),
                        );
                    }
                }
            }
        }
    }
}
