//! Batched direction-optimization equivalence suite.
//!
//! The direction policy is a pure Phase-1 strategy: levels are
//! synchronous, so `run_batch` distances must be **bit-identical** across
//! `topdown` / `bottomup` / `diropt` and equal to the serial per-root
//! oracle — on every partition mode, for duplicate and partial batches
//! alike. On top of the equivalence, the α/β switch must honor its
//! hysteresis contract (switch bottom-up only on a growing frontier, back
//! only on a shrinking one below `V/β`; `α = 0` disables bottom-up,
//! `β = 0` latches it), and the pooled Phase-2 merge path must reproduce
//! the sequential merge bit for bit.

use butterfly_bfs::bfs::msbfs::ms_bfs;
use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::config::DirectionMode;
use butterfly_bfs::coordinator::{BatchResult, EngineConfig, QuerySession, TraversalPlan};
use butterfly_bfs::graph::csr::{Csr, VertexId};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::graph::gen::structured::{grid2d, path, star};
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::util::propcheck::{forall, gen, Config};

fn session_for(g: &Csr, cfg: EngineConfig) -> QuerySession {
    TraversalPlan::build(g, cfg).expect("valid plan").session()
}

const DIRECTIONS: [DirectionMode; 3] = [
    DirectionMode::TopDown,
    DirectionMode::BottomUp,
    DirectionMode::DirOpt { alpha: 15, beta: 18 },
];

/// Run `roots` through `run_batch` under every direction policy on `base`
/// and assert all lanes' distances are bit-identical to each other and to
/// the serial oracle.
fn check_direction_equivalence(g: &Csr, base: EngineConfig, roots: &[VertexId]) {
    let mut results: Vec<BatchResult> = Vec::new();
    for direction in DIRECTIONS {
        let mut session = session_for(g, EngineConfig { direction, ..base.clone() });
        let b = session.run_batch(roots).unwrap();
        session.assert_batch_agreement().unwrap();
        results.push(b);
    }
    for (lane, &r) in roots.iter().enumerate() {
        let want = serial_bfs(g, r);
        for (b, direction) in results.iter().zip(DIRECTIONS) {
            assert_eq!(
                b.dist(lane),
                &want[..],
                "{direction:?} lane {lane} root {r}"
            );
        }
    }
    // Reached-pair totals agree too (same information, cheaper signal).
    assert_eq!(results[0].reached_pairs(), results[1].reached_pairs());
    assert_eq!(results[0].reached_pairs(), results[2].reached_pairs());
}

#[test]
fn directions_equivalent_one_d_across_node_counts() {
    let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 77);
    let roots: Vec<VertexId> = (0..64u32).map(|i| (i * 13) % 1024).collect();
    for (nodes, fanout) in [(1usize, 1u32), (4, 1), (16, 4), (9, 2)] {
        check_direction_equivalence(&g, EngineConfig::dgx2(nodes, fanout), &roots);
    }
}

#[test]
fn directions_equivalent_two_d_grids() {
    let (g, _) = uniform_random(700, 8, 19);
    let roots: Vec<VertexId> = (0..32u32).map(|i| (i * 17) % 700).collect();
    for (rows, cols) in [(4u32, 4u32), (2, 3), (1, 5), (5, 1)] {
        check_direction_equivalence(&g, EngineConfig::dgx2_2d(rows, cols), &roots);
    }
}

#[test]
fn directions_equivalent_duplicate_and_partial_batches() {
    let (g, _) = uniform_random(400, 6, 2);
    for roots in [
        vec![5u32],
        vec![1, 1, 1],
        vec![0, 399, 7, 7, 200],
        vec![9u32; 64],
    ] {
        check_direction_equivalence(&g, EngineConfig::dgx2(8, 4), &roots);
        check_direction_equivalence(&g, EngineConfig::dgx2_2d(2, 2), &roots);
    }
}

/// The tentpole coverage: wide batches at W ∈ {2, 4, 8} remain
/// direction-invariant and serial-exact in both partition modes —
/// including a duplicate-heavy 200-lane batch (coalescing masks span
/// word boundaries) and a partial 130-lane batch (unused high words stay
/// silent).
#[test]
fn directions_equivalent_wide_batches_one_d_and_two_d() {
    let (g, _) = uniform_random(500, 8, 23);
    let wide_sets: Vec<Vec<VertexId>> = vec![
        (0..96u32).map(|i| (i * 11) % 500).collect(), // W = 2
        (0..130u32).map(|i| (i * 7 + 3) % 500).collect(), // W = 4, partial
        (0..200u32).map(|i| if i % 3 == 0 { 42 } else { (i * 13) % 500 }).collect(),
        (0..260u32).map(|i| (i * 17) % 500).collect(), // W = 8, partial
    ];
    for roots in &wide_sets {
        check_direction_equivalence(&g, EngineConfig::dgx2(8, 4), roots);
        check_direction_equivalence(&g, EngineConfig::dgx2_2d(2, 3), roots);
    }
}

/// Wide bottom-up against the wide bit-parallel oracle: the W-word
/// `expand_bottom_up_batch` kernel (word-wise accumulate, all-missing-
/// lanes early exit) reproduces `ms_bfs` exactly at 256 lanes.
#[test]
fn wide_bottom_up_matches_bit_parallel_oracle_exactly() {
    let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 7);
    let roots: Vec<VertexId> = (0..256u32).map(|i| (i * 3) % 1024).collect();
    let cfg = EngineConfig {
        direction: DirectionMode::BottomUp,
        ..EngineConfig::dgx2(8, 2)
    };
    let mut session = session_for(&g, cfg);
    let b = session.run_batch(&roots).unwrap();
    session.assert_batch_agreement().unwrap();
    let want = ms_bfs(&g, &roots);
    for lane in 0..roots.len() {
        assert_eq!(b.dist(lane), want.dist(lane), "lane {lane}");
    }
    assert_eq!(b.metrics().lane_words, 4);
    assert!(b.metrics().levels.iter().all(|l| l.bottom_up));
}

#[test]
fn directions_equivalent_structured_graphs() {
    for g in [path(40), star(300), grid2d(8, 9)] {
        let n = g.num_vertices() as VertexId;
        let roots = vec![0, n - 1, n / 2, 0];
        check_direction_equivalence(&g, EngineConfig::dgx2(4, 2), &roots);
    }
}

#[test]
fn bottom_up_matches_bit_parallel_oracle_exactly() {
    let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 3);
    let roots: Vec<VertexId> = (0..48u32).map(|i| i * 7).collect();
    let cfg = EngineConfig {
        direction: DirectionMode::BottomUp,
        ..EngineConfig::dgx2(16, 4)
    };
    let mut session = session_for(&g, cfg);
    let b = session.run_batch(&roots).unwrap();
    let want = ms_bfs(&g, &roots);
    for lane in 0..roots.len() {
        assert_eq!(b.dist(lane), want.dist(lane), "lane {lane}");
    }
    assert_eq!(b.reached_pairs(), want.reached_pairs());
    // Every level is tagged bottom-up in the metrics.
    assert!(b.metrics().levels.iter().all(|l| l.bottom_up));
    assert_eq!(b.metrics().bottom_up_edges(), b.metrics().edges_examined());
}

#[test]
fn diropt_batch_saves_edges_on_dense_frontier_rmat() {
    // The tentpole's acceptance shape (the committed BENCH_engine.json
    // shows the same on the fixed protocol configs): on a low-diameter
    // RMAT batch, diropt must (a) actually switch bottom-up, (b) inspect
    // fewer edges than pure top-down overall, and (c) win at the densest
    // level specifically.
    let (g, _) = kronecker(KroneckerParams::graph500(11, 16), 13);
    let roots: Vec<VertexId> =
        butterfly_bfs::bfs::msbfs::sample_batch_roots(&g, 64, 0xBEEF);
    let mut td = session_for(&g, EngineConfig::dgx2(16, 4));
    let mut dopt = session_for(
        &g,
        EngineConfig {
            direction: DirectionMode::diropt(),
            ..EngineConfig::dgx2(16, 4)
        },
    );
    let btd = td.run_batch(&roots).unwrap();
    let bdo = dopt.run_batch(&roots).unwrap();
    for lane in 0..roots.len() {
        assert_eq!(btd.dist(lane), bdo.dist(lane), "lane {lane}");
    }
    let (mtd, mdo) = (btd.metrics(), bdo.metrics());
    assert!(mdo.bottom_up_levels() >= 1, "diropt never switched");
    assert!(
        mdo.edges_examined() < mtd.edges_examined(),
        "diropt {} vs topdown {}",
        mdo.edges_examined(),
        mtd.edges_examined()
    );
    let dense = mtd
        .levels
        .iter()
        .max_by_key(|l| l.frontier)
        .expect("nonempty run");
    let dense_do = &mdo.levels[dense.level as usize];
    assert!(dense_do.bottom_up, "densest level should run bottom-up");
    assert!(
        dense_do.edges_examined < dense.edges_examined,
        "dense level: diropt {} vs topdown {}",
        dense_do.edges_examined,
        dense.edges_examined
    );
}

#[test]
fn alpha_zero_disables_bottom_up_beta_zero_latches_it() {
    let (g, _) = kronecker(KroneckerParams::graph500(10, 16), 5);
    let roots: Vec<VertexId> = (0..64u32).map(|i| (i * 3) % 1024).collect();
    // α = 0: the TD→BU condition can never fire — pure top-down.
    let mut s = session_for(
        &g,
        EngineConfig {
            direction: DirectionMode::DirOpt { alpha: 0, beta: 18 },
            ..EngineConfig::dgx2(8, 2)
        },
    );
    let b = s.run_batch(&roots).unwrap();
    assert_eq!(b.metrics().bottom_up_levels(), 0);
    // Aggressive α with β = 0: once bottom-up, never back.
    let mut s = session_for(
        &g,
        EngineConfig {
            direction: DirectionMode::DirOpt { alpha: 1_000_000, beta: 0 },
            ..EngineConfig::dgx2(8, 2)
        },
    );
    let b = s.run_batch(&roots).unwrap();
    let tags: Vec<bool> = b.metrics().levels.iter().map(|l| l.bottom_up).collect();
    if let Some(first_bu) = tags.iter().position(|&t| t) {
        assert!(
            tags[first_bu..].iter().all(|&t| t),
            "β = 0 must latch bottom-up: {tags:?}"
        );
    }
    for (lane, &r) in roots.iter().enumerate() {
        assert_eq!(b.dist(lane), &serial_bfs(&g, r)[..], "lane {lane}");
    }
}

/// The α/β hysteresis contract, checked against the recorded per-level
/// trace: a TD→BU transition requires a *growing* frontier; a BU→TD
/// transition requires a *shrinking* frontier strictly below `V/β`.
/// (These are exactly the guards at the switch boundary — the regression
/// this test pins is the switch firing on the wrong side of them.)
fn assert_hysteresis(b: &BatchResult, num_vertices: u64, beta: u64) {
    let levels = &b.metrics().levels;
    for w in levels.windows(2) {
        let (prev, cur) = (&w[0], &w[1]);
        if !prev.bottom_up && cur.bottom_up {
            assert!(
                cur.frontier > prev.frontier,
                "TD->BU at level {} without growth: {} -> {}",
                cur.level,
                prev.frontier,
                cur.frontier
            );
        }
        if prev.bottom_up && !cur.bottom_up {
            assert!(
                cur.frontier <= prev.frontier,
                "BU->TD at level {} while growing: {} -> {}",
                cur.level,
                prev.frontier,
                cur.frontier
            );
            assert!(
                cur.frontier < num_vertices / beta,
                "BU->TD at level {} above V/beta: {} >= {}/{}",
                cur.level,
                cur.frontier,
                num_vertices,
                beta
            );
        }
    }
}

#[test]
fn switch_hysteresis_holds_at_the_boundary() {
    // A web-like graph (dense core + deep strands) drives the frontier
    // up through the core and back down the strands, crossing the switch
    // boundary in both directions.
    let spec = butterfly_bfs::graph::gen::table1_suite()
        .into_iter()
        .find(|s| s.name == "webbase-like")
        .unwrap();
    let g = spec.generate_scaled(-9);
    let roots: Vec<VertexId> =
        butterfly_bfs::bfs::msbfs::sample_batch_roots(&g, 48, 11);
    for (alpha, beta) in [(15u64, 18u64), (1, 1), (4, 64), (100, 2)] {
        let mut s = session_for(
            &g,
            EngineConfig {
                direction: DirectionMode::DirOpt { alpha, beta },
                ..EngineConfig::dgx2(8, 2)
            },
        );
        let b = s.run_batch(&roots).unwrap();
        assert_hysteresis(&b, g.num_vertices() as u64, beta);
        for (lane, &r) in roots.iter().enumerate() {
            assert_eq!(
                b.dist(lane),
                &serial_bfs(&g, r)[..],
                "alpha={alpha} beta={beta} lane {lane}"
            );
        }
    }
}

#[test]
fn property_batch_directions_equal_serial() {
    forall(Config::cases(18), "run_batch direction-invariant == serial", |rng| {
        let n = gen::usize_in(rng, 10, 300);
        let ef = gen::usize_in(rng, 1, 6) as u32;
        // One case in four crosses a lane-word boundary.
        let b = if rng.next_below(4) == 0 {
            gen::usize_in(rng, 65, 160)
        } else {
            gen::usize_in(rng, 1, 32)
        };
        let (g, _) = uniform_random(n, ef, rng.next_u64());
        let roots: Vec<VertexId> =
            (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
        let base = if rng.next_below(2) == 0 {
            let nodes = gen::usize_in(rng, 1, 8.min(n));
            EngineConfig::dgx2(nodes, gen::usize_in(rng, 1, 4) as u32)
        } else {
            let rows = gen::usize_in(rng, 1, 4.min(n)) as u32;
            let cols = gen::usize_in(rng, 1, 4.min(n)) as u32;
            EngineConfig::dgx2_2d(rows, cols)
        };
        let mut ok = true;
        for direction in DIRECTIONS {
            let mut session = TraversalPlan::build(&g, EngineConfig { direction, ..base.clone() })
                .unwrap()
                .session();
            let batch = session.run_batch(&roots).unwrap();
            ok &= session.assert_batch_agreement().is_ok()
                && roots
                    .iter()
                    .enumerate()
                    .all(|(lane, &r)| batch.dist(lane) == &serial_bfs(&g, r)[..]);
        }
        (ok, format!("n={n} ef={ef} b={b}"))
    });
}

/// Pooled Phase-2 merging must be bit-identical to sequential merging —
/// distances *and* the integer level accounting — for single-root and
/// batched queries, all directions, both partition modes.
#[test]
fn property_pooled_phase2_bit_identical() {
    forall(Config::cases(30), "parallel_phase2 == sequential", |rng| {
        let n = gen::usize_in(rng, 10, 250);
        let ef = gen::usize_in(rng, 1, 6) as u32;
        let (g, _) = uniform_random(n, ef, rng.next_u64());
        let base = if rng.next_below(2) == 0 {
            let nodes = gen::usize_in(rng, 2, 8.min(n));
            EngineConfig::dgx2(nodes, gen::usize_in(rng, 1, 4) as u32)
        } else {
            let rows = gen::usize_in(rng, 1, 4.min(n)) as u32;
            let cols = gen::usize_in(rng, 2, 4.min(n)) as u32;
            EngineConfig::dgx2_2d(rows, cols)
        };
        let direction = match rng.next_below(3) {
            0 => DirectionMode::TopDown,
            1 => DirectionMode::BottomUp,
            _ => DirectionMode::diropt(),
        };
        let cfg = EngineConfig { direction, ..base };
        let mut seq = session_for(&g, cfg.clone());
        let mut par = session_for(&g, EngineConfig { parallel_phase2: true, ..cfg });
        let mut ok = true;
        // Single-root.
        let root = rng.next_usize(n) as u32;
        let rs = seq.run(root).unwrap();
        let rp = par.run(root).unwrap();
        ok &= par.assert_agreement().is_ok() && rs.dist() == rp.dist();
        for (a, c) in rs.metrics().levels.iter().zip(&rp.metrics().levels) {
            ok &= a.frontier == c.frontier
                && a.edges_examined == c.edges_examined
                && a.discovered == c.discovered
                && a.messages == c.messages
                && a.bytes == c.bytes
                && a.bottom_up == c.bottom_up;
        }
        // Batched.
        let b = gen::usize_in(rng, 1, 24);
        let roots: Vec<VertexId> =
            (0..b).map(|_| rng.next_usize(n) as VertexId).collect();
        let bs = seq.run_batch(&roots).unwrap();
        let bp = par.run_batch(&roots).unwrap();
        ok &= par.assert_batch_agreement().is_ok();
        for lane in 0..roots.len() {
            ok &= bs.dist(lane) == bp.dist(lane);
        }
        for (a, c) in bs.metrics().levels.iter().zip(&bp.metrics().levels) {
            ok &= a.frontier == c.frontier
                && a.edges_examined == c.edges_examined
                && a.discovered == c.discovered
                && a.messages == c.messages
                && a.bytes == c.bytes
                && a.bottom_up == c.bottom_up;
        }
        (ok, format!("n={n} ef={ef} {direction:?}"))
    });
}

/// Both pools at once (Phase 1 + Phase 2) still reproduce sequential
/// results — the configuration the CLI's `--parallel --parallel-sync`
/// smoke exercises.
#[test]
fn both_phases_pooled_match_sequential() {
    let (g, _) = kronecker(KroneckerParams::graph500(10, 8), 4);
    let roots: Vec<VertexId> = (0..64u32).map(|i| (i * 9) % 1024).collect();
    for base in [EngineConfig::dgx2(8, 4), EngineConfig::dgx2_2d(2, 4)] {
        let cfg = EngineConfig {
            direction: DirectionMode::diropt(),
            ..base
        };
        let mut seq = session_for(&g, cfg.clone());
        let mut par = session_for(
            &g,
            EngineConfig {
                parallel_phase1: true,
                parallel_phase2: true,
                ..cfg
            },
        );
        let bs = seq.run_batch(&roots).unwrap();
        let bp = par.run_batch(&roots).unwrap();
        par.assert_batch_agreement().unwrap();
        for lane in 0..roots.len() {
            assert_eq!(bs.dist(lane), bp.dist(lane), "lane {lane}");
        }
        assert_eq!(bs.metrics().bytes(), bp.metrics().bytes());
        assert_eq!(
            bs.metrics().edges_examined(),
            bp.metrics().edges_examined()
        );
        assert_eq!(
            bs.metrics().bottom_up_levels(),
            bp.metrics().bottom_up_levels()
        );
    }
}
