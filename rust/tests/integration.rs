//! Cross-module integration tests: the full pipeline (generate → ETL →
//! partition → distributed traversal → metrics) exercised end-to-end,
//! including every pattern/fanout/payload combination against the serial
//! oracle on the whole analog suite.

use butterfly_bfs::bfs::dirop::{diropt_bfs, DirOptParams};
use butterfly_bfs::bfs::serial::{serial_bfs, INF};
use butterfly_bfs::bfs::topdown::topdown_bfs;
use butterfly_bfs::coordinator::{EngineConfig, PatternKind, PayloadEncoding, TraversalPlan};
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::graph::{io, props};
use butterfly_bfs::harness::roots::{sample_roots, RootProtocol};
use butterfly_bfs::partition::one_d::partition_1d;

/// Every suite graph (tiny scale), every engine flavor, multiple roots:
/// distributed == serial.
#[test]
fn full_suite_distributed_equals_serial() {
    let proto = RootProtocol { num_roots: 3, trim: 0, seed: 7 };
    for spec in table1_suite() {
        let g = spec.generate_scaled(-7);
        let roots = sample_roots(&g, &proto);
        for fanout in [1u32, 4] {
            let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(16, fanout))
                .unwrap()
                .session();
            for &root in &roots {
                let r = session.run(root).unwrap();
                session.assert_agreement().unwrap_or_else(|e| {
                    panic!("{} f{fanout} root {root}: {e}", spec.name)
                });
                assert_eq!(
                    r.dist(),
                    &serial_bfs(&g, root)[..],
                    "{} f{fanout} root {root}",
                    spec.name
                );
            }
        }
    }
}

/// All single-node baselines agree with each other on the suite.
#[test]
fn baselines_agree_across_suite() {
    for spec in table1_suite() {
        let g = spec.generate_scaled(-7);
        let want = serial_bfs(&g, 0);
        assert_eq!(topdown_bfs(&g, 0, true).dist, want, "{} td", spec.name);
        assert_eq!(
            diropt_bfs(&g, 0, DirOptParams::default()).dist,
            want,
            "{} do",
            spec.name
        );
    }
}

/// Payload encodings change bytes but never results.
#[test]
fn payload_encoding_is_semantically_transparent() {
    let g = table1_suite()[6].generate_scaled(-7); // kron-like
    let mut results = Vec::new();
    let mut bytes = Vec::new();
    for payload in [PayloadEncoding::Queue, PayloadEncoding::Bitmap, PayloadEncoding::Auto] {
        let cfg = EngineConfig { payload, ..EngineConfig::dgx2(8, 4) };
        let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
        let r = session.run(0).unwrap();
        results.push(r.dist().to_vec());
        bytes.push(r.metrics().bytes());
    }
    assert_eq!(results[0], results[1]);
    assert_eq!(results[1], results[2]);
    // Auto never ships more than either pure encoding.
    assert!(bytes[2] <= bytes[0].min(bytes[1]), "{bytes:?}");
}

/// The three patterns produce identical distances and identical
/// per-level discovery counts (they only reshape the communication).
#[test]
fn patterns_only_change_communication() {
    let g = table1_suite()[7].generate_scaled(-7); // urand-like
    let mut dists = Vec::new();
    let mut discoveries = Vec::new();
    let mut messages = Vec::new();
    for pattern in [
        PatternKind::Butterfly { fanout: 1 },
        PatternKind::AllToAllConcurrent,
        PatternKind::AllToAllIterative,
    ] {
        let cfg = EngineConfig { pattern, ..EngineConfig::dgx2(9, 1) };
        let mut session = TraversalPlan::build(&g, cfg).unwrap().session();
        let r = session.run(3).unwrap();
        let m = r.metrics();
        dists.push(r.dist().to_vec());
        discoveries.push(m.levels.iter().map(|l| l.discovered).collect::<Vec<_>>());
        messages.push(m.messages());
    }
    assert_eq!(dists[0], dists[1]);
    assert_eq!(dists[1], dists[2]);
    assert_eq!(discoveries[0], discoveries[1]);
    assert_eq!(discoveries[1], discoveries[2]);
    // Butterfly at 9 nodes sends fewer messages than either all-to-all.
    assert!(messages[0] < messages[1], "{messages:?}");
    assert_eq!(messages[1], messages[2]);
}

/// Graph I/O round-trips through both formats feed the engine correctly.
#[test]
fn io_roundtrip_through_engine() {
    let g = table1_suite()[3].generate_scaled(-8); // twitter-like, tiny
    let dir = std::env::temp_dir();
    let bin = dir.join(format!("bbfs-int-{}.bbfs", std::process::id()));
    let txt = dir.join(format!("bbfs-int-{}.txt", std::process::id()));
    io::write_binary(&g, &bin).unwrap();
    io::write_edge_list(&g, &txt).unwrap();
    let g_bin = io::read_binary(&bin).unwrap();
    let (g_txt, _) = io::read_edge_list(&txt, Some(g.num_vertices())).unwrap();
    assert_eq!(g, g_bin);
    assert_eq!(g, g_txt);
    let mut session = TraversalPlan::build(&g_bin, EngineConfig::dgx2(4, 2))
        .unwrap()
        .session();
    let r = session.run(0).unwrap();
    assert_eq!(r.dist(), &serial_bfs(&g, 0)[..]);
    std::fs::remove_file(&bin).ok();
    std::fs::remove_file(&txt).ok();
}

/// Suite analogs land in the diameter class of their paper originals.
#[test]
fn suite_diameter_classes() {
    let suite = table1_suite();
    let diam = |name: &str, delta: i32| {
        let spec = suite.iter().find(|s| s.name == name).unwrap();
        let g = spec.generate_scaled(delta);
        props::pseudo_diameter(&g, 0)
    };
    // webbase-like must be high-diameter (tail), kron-like small-world.
    let webbase = diam("webbase-like", -6);
    let kron = diam("kron-like", -6);
    let urand = diam("urand-like", -6);
    assert!(webbase > 100, "webbase diameter {webbase} (tail = 400)");
    assert!(kron < 15, "kron diameter {kron}");
    assert!(urand < 15, "urand diameter {urand}");
}

/// Per-level frontier sizes from the engine match the serial oracle's
/// level population (full metric-path check).
#[test]
fn level_populations_match_oracle() {
    let g = table1_suite()[8].generate_scaled(-7); // moliere-like
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(8, 4))
        .unwrap()
        .session();
    let r = session.run(0).unwrap();
    let m = r.metrics();
    let d = serial_bfs(&g, 0);
    let max_d = d.iter().filter(|&&x| x != INF).max().copied().unwrap();
    for lvl in 0..=max_d {
        let pop = d.iter().filter(|&&x| x == lvl).count() as u64;
        assert_eq!(
            m.levels[lvl as usize].frontier, pop,
            "level {lvl} population"
        );
    }
}

/// Partition ownership is exhaustive and consistent with engine routing:
/// every vertex's distance is set by exactly the rounds of sync implied by
/// its discovery level (smoke: run on a partitioned star where all
/// cross-node traffic happens at level 1).
#[test]
fn star_graph_cross_node_routing() {
    use butterfly_bfs::graph::gen::structured::star;
    let g = star(1000);
    let part = partition_1d(&g, 8);
    assert_eq!(part.owner_of(0), 0);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(8, 1))
        .unwrap()
        .session();
    let r = session.run(0).unwrap();
    let m = r.metrics();
    assert_eq!(m.depth(), 2);
    assert_eq!(m.reached, 1000);
    // Level 0: root expands 999 edges; every other node learns the full
    // frontier through the butterfly.
    assert_eq!(m.levels[0].edges_examined, 999);
    session.assert_agreement().unwrap();
}

/// Metrics invariants over a random workload: totals equal sums, comm
/// fraction in [0,1], GTEPS positive and finite.
#[test]
fn metrics_invariants() {
    let g = table1_suite()[4].generate_scaled(-7);
    let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4))
        .unwrap()
        .session();
    let r = session.run(0).unwrap();
    let m = r.metrics();
    assert_eq!(
        m.edges_examined(),
        m.levels.iter().map(|l| l.edges_examined).sum::<u64>()
    );
    assert!(m.sim_comm_fraction() >= 0.0 && m.sim_comm_fraction() <= 1.0);
    assert!(m.sim_gteps().is_finite() && m.sim_gteps() > 0.0);
    assert!(m.wall_seconds > 0.0);
    let total_discovered: u64 = m.levels.iter().map(|l| l.discovered).sum();
    assert_eq!(total_discovered + 1, m.reached, "discoveries + root = reached");
}
