//! Property tests locking down the butterfly schedule (§3 of the paper):
//! completeness after `depth_for(cn)` rounds for every node count and
//! fanout the evaluation sweeps, the padded virtual-node routing scheme,
//! and the Fig 1(f) 9-node pathology as an explicit regression test.

use butterfly_bfs::comm::analysis::{propagate_knowledge, verify_full_coverage};
use butterfly_bfs::comm::{Butterfly, CommPattern};
use butterfly_bfs::util::propcheck::{forall, gen, Config};

/// Exhaustive completeness sweep: for every `cn ∈ 2..=32` and fanout in
/// {1, 2, 4, 8, 16}, the schedule is valid, runs exactly `depth_for(cn)`
/// rounds, and leaves every node holding every node's frontier block.
#[test]
fn completeness_exhaustive_cn2_to_32_all_fanouts() {
    for cn in 2..=32u32 {
        for f in [1u32, 2, 4, 8, 16] {
            let bf = Butterfly::new(f);
            let s = bf.schedule(cn);
            s.validate().unwrap_or_else(|e| panic!("cn={cn} f={f}: {e}"));
            assert_eq!(s.depth() as u32, bf.depth_for(cn), "cn={cn} f={f}");
            verify_full_coverage(&s).unwrap_or_else(|e| panic!("cn={cn} f={f}: {e}"));
            // Contribution 4's receive-buffer bound O(f·V): a node never
            // receives from more than radix−1 distinct holders per round.
            assert!(
                s.max_recvs_per_round() <= (bf.radix() - 1) as u64,
                "cn={cn} f={f}: {} receives",
                s.max_recvs_per_round()
            );
        }
    }
}

/// Coverage is achieved *exactly* at the final round, not before (for
/// power-of-radix node counts, where no padding blurs the picture): after
/// `depth − 1` rounds at least one node is still missing a block.
#[test]
fn coverage_not_reached_early_at_powers_of_radix() {
    for (f, cn) in [(1u32, 16u32), (1, 32), (2, 16), (4, 16), (4, 64), (8, 64)] {
        let bf = Butterfly::new(f);
        let mut s = bf.schedule(cn);
        assert!(s.depth() >= 1);
        s.rounds.pop();
        let know = propagate_knowledge(&s);
        let want: u128 = (1u128 << cn) - 1;
        assert!(
            know.iter().any(|&k| k != want),
            "f={f} cn={cn}: coverage already complete one round early"
        );
    }
}

/// The padded virtual-node scheme: for non-power-of-radix node counts the
/// id space is padded to `radix^depth`, and any partner id beyond the real
/// range must be served by the *last real node* `cn − 1`. Checked against
/// an independent re-derivation of the digit-exchange partners.
#[test]
fn virtual_blocks_route_to_last_real_node() {
    for cn in 2..=32u32 {
        for f in [1u32, 2, 4, 8, 16] {
            let bf = Butterfly::new(f);
            let r = bf.radix() as u64;
            for round in 0..bf.depth_for(cn) {
                let stride = r.pow(round);
                for g in 0..cn as u64 {
                    let digit = (g / stride) % r;
                    let base = g - digit * stride;
                    let mut expect: Vec<u32> = Vec::new();
                    let mut saw_virtual = false;
                    for j in 0..r {
                        if j == digit {
                            continue;
                        }
                        let partner = base + j * stride;
                        let holder = if partner >= cn as u64 {
                            saw_virtual = true;
                            cn - 1
                        } else {
                            partner as u32
                        };
                        if holder != g as u32 && !expect.contains(&holder) {
                            expect.push(holder);
                        }
                    }
                    let got = bf.butterfly_direction(cn, g as u32, round);
                    assert_eq!(got, expect, "cn={cn} f={f} round={round} g={g}");
                    // Every source must be a real node; when a virtual
                    // partner occurred, cn−1 is the only legal stand-in.
                    assert!(got.iter().all(|&s| s < cn), "cn={cn} f={f} g={g}");
                    if saw_virtual && !expect.is_empty() {
                        assert!(
                            got.contains(&(cn - 1)) || g as u32 == cn - 1,
                            "cn={cn} f={f} round={round} g={g}: virtual block \
                             not routed to node {}",
                            cn - 1
                        );
                    }
                }
            }
        }
    }
}

/// The paper's Fig 1(f) pathology, locked as a regression test: 9 nodes at
/// fanout 1 force node 8 to serve all eight other nodes in the final round
/// (one NIC, eight sends), while 8 nodes have no hotspot at all.
#[test]
fn fig1f_nine_node_regression() {
    let s = Butterfly::new(1).schedule(9);
    assert_eq!(s.depth(), 4);
    verify_full_coverage(&s).unwrap();
    let last = s.rounds.last().unwrap();
    let receivers: Vec<u32> = last.iter().filter(|t| t.src == 8).map(|t| t.dst).collect();
    assert_eq!(receivers.len(), 8, "node 8 must serve all others: {last:?}");
    let mut sorted = receivers.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0u32..8).collect::<Vec<_>>());
    assert_eq!(s.max_sends_per_round(), 8);
    // Contrast: the 8-node schedule is perfectly balanced …
    let s8 = Butterfly::new(1).schedule(8);
    assert_eq!(s8.max_sends_per_round(), 1);
    // … and fanout 4 at 9 nodes bounds the hotspot well below 8 sends.
    let s9f4 = Butterfly::new(4).schedule(9);
    assert!(
        s9f4.max_sends_per_round() < 8,
        "f4 hotspot {}",
        s9f4.max_sends_per_round()
    );
}

/// Randomized sweep beyond the exhaustive grid: any (cn ≤ 48, f ≤ 16)
/// pair keeps the invariants.
#[test]
fn property_random_cn_fanout_complete_and_bounded() {
    forall(Config::cases(64), "butterfly complete + recv-bounded", |rng| {
        let cn = gen::usize_in(rng, 2, 48) as u32;
        let f = gen::usize_in(rng, 1, 16) as u32;
        let bf = Butterfly::new(f);
        let s = bf.schedule(cn);
        let ok = s.validate().is_ok()
            && verify_full_coverage(&s).is_ok()
            && s.depth() as u32 == bf.depth_for(cn)
            && s.max_recvs_per_round() <= (bf.radix() - 1) as u64;
        (ok, format!("cn={cn} f={f}"))
    });
}

/// Knowledge growth at fanout f is geometric with ratio radix: after round
/// i every node of a power-of-radix schedule knows exactly radix^(i+1)
/// blocks (Fig 1(b)–(e) / Fig 2 generalized).
#[test]
fn knowledge_grows_geometrically_at_powers_of_radix() {
    for (f, cn) in [(1u32, 32u32), (2, 32), (4, 64), (8, 64)] {
        let bf = Butterfly::new(f);
        let r = bf.radix();
        let s = bf.schedule(cn);
        let mut know: Vec<u128> = (0..cn).map(|g| 1u128 << g).collect();
        for (i, round) in s.rounds.iter().enumerate() {
            let snap = know.clone();
            for t in round {
                know[t.dst as usize] |= snap[t.src as usize];
            }
            let expect = (r as u64).pow(i as u32 + 1).min(cn as u64);
            for (g, k) in know.iter().enumerate() {
                assert_eq!(
                    k.count_ones() as u64,
                    expect,
                    "f={f} cn={cn} round={i} node={g}"
                );
            }
        }
    }
}
