//! The plan/session concurrency acceptance: N threads sharing one
//! `Arc<TraversalPlan>` through independent `QuerySession`s must produce
//! results bit-identical to running the same queries sequentially on a
//! single session — distances, reach, depth, and every deterministic
//! per-level metric — in both the 1D butterfly and 2D fold/expand modes,
//! for single-root and batched traversals.

use butterfly_bfs::coordinator::{
    BatchResult, EngineConfig, QuerySession, TraversalPlan, TraversalResult,
};
use butterfly_bfs::graph::csr::VertexId;
use butterfly_bfs::graph::gen::urand::uniform_random;
use std::sync::Arc;
use std::thread;

/// Everything deterministic about a single-root result.
fn run_key(r: &TraversalResult) -> (Vec<u32>, u64, usize, Vec<(u64, u64, u64, u64, u64)>) {
    (
        r.dist().to_vec(),
        r.reached(),
        r.depth(),
        r.metrics()
            .levels
            .iter()
            .map(|l| (l.frontier, l.edges_examined, l.discovered, l.messages, l.bytes))
            .collect(),
    )
}

/// Everything deterministic about a batched result.
fn batch_key(b: &BatchResult) -> (Vec<u32>, u64, usize, u64, u64, u64) {
    let dist: Vec<u32> = (0..b.num_roots()).flat_map(|l| b.dist(l).to_vec()).collect();
    (
        dist,
        b.reached_pairs(),
        b.depth(),
        b.metrics().messages(),
        b.metrics().bytes(),
        b.metrics().sync_rounds,
    )
}

/// Four threads, two roots each, one shared plan — versus one session
/// running all eight roots back to back.
fn concurrent_equals_sequential(cfg: EngineConfig) {
    let (g, _) = uniform_random(700, 8, 21);
    let plan = Arc::new(TraversalPlan::build(&g, cfg).unwrap());
    let roots: Vec<VertexId> = (0..8u32).map(|i| (i * 97) % 700).collect();

    let mut session = plan.session();
    let sequential: Vec<_> = roots
        .iter()
        .map(|&r| run_key(&session.run(r).unwrap()))
        .collect();

    let mut handles = Vec::new();
    for chunk in roots.chunks(2) {
        let plan = Arc::clone(&plan);
        let chunk = chunk.to_vec();
        handles.push(thread::spawn(move || {
            let mut s = plan.session();
            chunk
                .iter()
                .map(|&r| run_key(&s.run(r).unwrap()))
                .collect::<Vec<_>>()
        }));
    }
    assert!(handles.len() >= 4, "acceptance demands >= 4 concurrent sessions");
    let concurrent: Vec<_> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();

    assert_eq!(sequential.len(), concurrent.len());
    for (i, (a, b)) in sequential.iter().zip(&concurrent).enumerate() {
        assert_eq!(a, b, "root {} differs between sequential and concurrent", roots[i]);
    }
}

#[test]
fn concurrent_sessions_bit_identical_1d() {
    concurrent_equals_sequential(EngineConfig::dgx2(8, 4));
    concurrent_equals_sequential(EngineConfig::dgx2(9, 1));
}

#[test]
fn concurrent_sessions_bit_identical_2d() {
    concurrent_equals_sequential(EngineConfig::dgx2_2d(2, 3));
    concurrent_equals_sequential(EngineConfig::dgx2_2d(4, 4));
}

#[test]
fn concurrent_sessions_bit_identical_with_parallel_phase1() {
    // Sessions that each spawn their own worker pool still agree.
    concurrent_equals_sequential(EngineConfig {
        parallel_phase1: true,
        ..EngineConfig::dgx2(8, 4)
    });
}

#[test]
fn concurrent_batch_sessions_bit_identical() {
    for cfg in [EngineConfig::dgx2(8, 2), EngineConfig::dgx2_2d(2, 2)] {
        let (g, _) = uniform_random(500, 6, 5);
        let plan = Arc::new(TraversalPlan::build(&g, cfg).unwrap());
        let batches: Vec<Vec<VertexId>> = (0..4u32)
            .map(|t| (0..16u32).map(move |i| (t * 131 + i * 17) % 500).collect())
            .collect();

        let mut session = plan.session();
        let sequential: Vec<_> = batches
            .iter()
            .map(|b| batch_key(&session.run_batch(b).unwrap()))
            .collect();

        let handles: Vec<_> = batches
            .iter()
            .cloned()
            .map(|b| {
                let plan = Arc::clone(&plan);
                thread::spawn(move || {
                    let mut s = plan.session();
                    batch_key(&s.run_batch(&b).unwrap())
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), sequential[i], "batch {i}");
        }
    }
}

#[test]
fn plan_and_results_cross_threads() {
    fn assert_send<T: Send>() {}
    fn assert_send_sync<T: Send + Sync>() {}
    // The plan is shared by reference across threads; results are handed
    // off between threads; sessions move into worker threads.
    assert_send_sync::<TraversalPlan>();
    assert_send_sync::<TraversalResult>();
    assert_send_sync::<BatchResult>();
    assert_send::<QuerySession>();
}
