//! Hierarchical-mode equivalence suite: the grid-of-islands engine
//! (butterfly inside each island, representative exchange across
//! islands, final representative -> island broadcast) must produce
//! distances bit-identical to the flat 1D butterfly, to the 2D
//! fold/expand comparator, and to `bfs::serial` across the analog graph
//! suite — single-root and wide batches up to 512 lanes, in all three
//! direction modes, including the degenerate one-island and
//! one-node-per-island grids. Vertex ownership stays 1D row slabs in
//! every mode, so no layout is allowed to drift by even one distance.
//! Schedule-validity property tests over islands × per_island ∈ 1..=8
//! live next to the engine in `coordinator::session`.

use butterfly_bfs::bfs::msbfs::ms_bfs;
use butterfly_bfs::bfs::serial::{serial_bfs, INF};
use butterfly_bfs::coordinator::{
    BatchWidth, DirectionMode, EngineConfig, TraversalPlan,
};
use butterfly_bfs::graph::csr::{Csr, VertexId};
use butterfly_bfs::graph::gen::structured::{grid2d, path, star};
use butterfly_bfs::graph::gen::table1_suite;

/// Island grids exercised everywhere below: square, skewed both ways,
/// and the two degenerate shapes (one island / one node per island).
const GRIDS: [(u32, u32); 6] = [(4, 4), (2, 8), (8, 2), (3, 3), (1, 4), (4, 1)];

fn hier_session(
    g: &Csr,
    islands: u32,
    per_island: u32,
) -> butterfly_bfs::coordinator::QuerySession {
    TraversalPlan::build(g, EngineConfig::dgx2_cluster_hier(islands, per_island, 4))
        .unwrap()
        .session()
}

/// Run the full four-way check on one graph/root: hierarchical (every
/// island grid) == 1D butterfly == 2D fold/expand == serial, with the
/// per-class accounting tiling the totals.
fn check_equivalence(g: &Csr, root: VertexId, label: &str) {
    let want = serial_bfs(g, root);
    for (islands, per_island) in GRIDS {
        let nodes = (islands * per_island) as usize;
        if nodes > g.num_vertices() {
            continue;
        }
        let mut flat = TraversalPlan::build(g, EngineConfig::dgx2(nodes, 4))
            .unwrap()
            .session();
        let r1 = flat.run(root).unwrap();
        let mut two_d = TraversalPlan::build(g, EngineConfig::dgx2_2d(islands, per_island))
            .unwrap()
            .session();
        let r2 = two_d.run(root).unwrap();
        let mut hier = hier_session(g, islands, per_island);
        let rh = hier.run(root).unwrap();
        hier.assert_agreement().unwrap();
        assert_eq!(
            rh.dist(),
            &want[..],
            "{label}: hier {islands}x{per_island} vs serial"
        );
        assert_eq!(
            rh.dist(),
            r1.dist(),
            "{label}: hier {islands}x{per_island} vs 1D"
        );
        assert_eq!(
            rh.dist(),
            r2.dist(),
            "{label}: hier {islands}x{per_island} vs 2D"
        );
        // Link-class accounting tiles the totals on every grid, and a
        // true grid actually uses both classes.
        let m = rh.metrics();
        assert_eq!(m.intra_messages() + m.inter_messages(), m.messages());
        assert_eq!(m.intra_bytes() + m.inter_bytes(), m.bytes());
        if islands > 1 && per_island > 1 {
            assert!(m.inter_messages() > 0, "{label}: {islands}x{per_island}");
            assert!(m.intra_messages() > 0, "{label}: {islands}x{per_island}");
        }
    }
}

/// Every suite graph at tiny scale, across all island grids.
#[test]
fn suite_hier_equals_one_d_two_d_serial() {
    for spec in table1_suite() {
        let g = spec.generate_scaled(-7);
        check_equivalence(&g, 0, spec.name);
    }
}

/// Structured graphs from both end roots.
#[test]
fn structured_graphs_all_roots() {
    for g in [path(40), star(50), grid2d(6, 8)] {
        let last = (g.num_vertices() - 1) as VertexId;
        check_equivalence(&g, 0, "structured");
        check_equivalence(&g, last, "structured/last");
    }
}

/// Disconnected graph: unreached vertices stay INF in hierarchical mode
/// exactly as in every other mode, on every node.
#[test]
fn disconnected_graph_unreached_stay_inf() {
    use butterfly_bfs::graph::builder::GraphBuilder;
    let mut b = GraphBuilder::new(40);
    for v in 1..20u32 {
        b.add_edge(0, v);
    }
    b.add_edge(30, 31); // island (the graph kind, not the topology kind)
    let (g, _) = b.build_undirected();
    check_equivalence(&g, 0, "disconnected");
    let mut session = hier_session(&g, 4, 4);
    let r = session.run(0).unwrap();
    assert_eq!(r.reached(), 20);
    assert_eq!(r.dist()[30], INF);
}

/// Wide batches through the grid-of-islands exchange: every lane width
/// class (64/128/256/512 mask words' worth of roots) matches the
/// multi-source oracle and the 2D comparator lane-for-lane.
#[test]
fn wide_batches_up_to_512_lanes_match_oracle_and_two_d() {
    use butterfly_bfs::graph::gen::uniform_random;
    let (g, _) = uniform_random(500, 6, 3);
    for width in [1usize, 64, 256, 512] {
        let roots: Vec<VertexId> =
            (0..width).map(|i| ((i * 7 + 1) % 500) as VertexId).collect();
        let batch_width = BatchWidth::for_lanes(width).unwrap();
        let cfg =
            EngineConfig { batch_width, ..EngineConfig::dgx2_cluster_hier(4, 2, 4) };
        let mut hier = TraversalPlan::build(&g, cfg).unwrap().session();
        let bh = hier.run_batch(&roots).unwrap();
        hier.assert_batch_agreement().unwrap();
        let cfg2 = EngineConfig { batch_width, ..EngineConfig::dgx2_2d(4, 2) };
        let mut two_d = TraversalPlan::build(&g, cfg2).unwrap().session();
        let b2 = two_d.run_batch(&roots).unwrap();
        let want = ms_bfs(&g, &roots);
        for lane in 0..width {
            assert_eq!(
                bh.dist(lane),
                want.dist(lane),
                "width {width} lane {lane} vs oracle"
            );
            assert_eq!(
                bh.dist(lane),
                b2.dist(lane),
                "width {width} lane {lane} vs 2D"
            );
        }
        let m = bh.metrics();
        assert_eq!(m.intra_messages() + m.inter_messages(), m.messages());
        assert!(m.inter_messages() > 0, "width {width}");
    }
}

/// Direction modes compose with the hierarchical exchange unchanged:
/// top-down, bottom-up, and direction-optimizing runs all land on the
/// same distances as serial and as the 2D engine under the same policy.
#[test]
fn direction_modes_equal_serial_and_two_d_on_suite_graph() {
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "kron-like")
        .unwrap();
    let g = spec.generate_scaled(-8);
    let want = serial_bfs(&g, 1);
    for direction in [
        DirectionMode::TopDown,
        DirectionMode::BottomUp,
        DirectionMode::diropt(),
    ] {
        let cfg =
            EngineConfig { direction, ..EngineConfig::dgx2_cluster_hier(2, 8, 4) };
        let mut hier = TraversalPlan::build(&g, cfg).unwrap().session();
        let rh = hier.run(1).unwrap();
        hier.assert_agreement().unwrap();
        assert_eq!(rh.dist(), &want[..], "{direction:?} vs serial");
        let cfg2 = EngineConfig { direction, ..EngineConfig::dgx2_2d(2, 8) };
        let mut two_d = TraversalPlan::build(&g, cfg2).unwrap().session();
        assert_eq!(
            rh.dist(),
            two_d.run(1).unwrap().dist(),
            "{direction:?} vs 2D"
        );
    }
}

/// Degenerate grids collapse to the flat butterfly: a 1×P grid is one
/// island, a P×1 grid makes every rank its own representative — both
/// must match the flat 1D engine exactly, wide batches included.
#[test]
fn degenerate_grids_match_flat_one_d() {
    use butterfly_bfs::bfs::msbfs::sample_batch_roots;
    use butterfly_bfs::graph::gen::uniform_random;
    let (g, _) = uniform_random(300, 5, 11);
    let roots = sample_batch_roots(&g, 8, 0x41E);
    let mut flat = TraversalPlan::build(&g, EngineConfig::dgx2(6, 4))
        .unwrap()
        .session();
    let rf = flat.run(2).unwrap();
    let bf = flat.run_batch(&roots).unwrap();
    for (islands, per_island) in [(1u32, 6u32), (6, 1)] {
        let mut hier = hier_session(&g, islands, per_island);
        let rh = hier.run(2).unwrap();
        assert_eq!(rh.dist(), rf.dist(), "grid {islands}x{per_island}");
        let bh = hier.run_batch(&roots).unwrap();
        for lane in 0..roots.len() {
            assert_eq!(
                bh.dist(lane),
                bf.dist(lane),
                "grid {islands}x{per_island} lane {lane}"
            );
        }
    }
}
