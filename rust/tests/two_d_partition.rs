//! Exhaustive `Partition2D` property coverage: for every grid shape
//! `rows, cols ∈ 1..=8` over every vertex count `|V| ∈ 1..=64` (including
//! ragged, non-divisible cuts), the checkerboard layout must satisfy the
//! routing invariants the 2D engine mode builds on:
//!
//! * both cut arrays cover `0..n` with monotone, non-overlapping,
//!   non-empty ranges;
//! * every edge block `(u, w)` is owned by *exactly one* processor;
//! * `owner_of_edge` is consistent with the per-axis range lookups;
//! * the block slabs partition the edge set exactly.

use butterfly_bfs::graph::builder::GraphBuilder;
use butterfly_bfs::graph::csr::Csr;
use butterfly_bfs::partition::Partition2D;
use butterfly_bfs::util::prng::Xoshiro256StarStar;

/// A graph with `n` vertices and a pseudo-random (possibly empty) edge
/// set — raw edge lists may contain duplicates and self-loops, which the
/// builder's ETL cleans, so ragged degree distributions are exercised.
fn random_graph(n: usize, seed: u64) -> Csr {
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let mut b = GraphBuilder::new(n);
    let m = (n * 2).min(200);
    for _ in 0..m {
        b.add_edge(rng.next_usize(n) as u32, rng.next_usize(n) as u32);
    }
    b.build_undirected().0
}

#[test]
fn exhaustive_grids_cuts_cover_and_are_monotone() {
    for n in 1..=64usize {
        let g = random_graph(n, n as u64);
        for rows in 1..=8.min(n as u32) {
            for cols in 1..=8.min(n as u32) {
                let p2 = Partition2D::new(&g, rows, cols);
                for (axis, cuts) in
                    [("row", &p2.row_cuts), ("col", &p2.col_cuts)]
                {
                    assert_eq!(cuts[0], 0, "n={n} {rows}x{cols} {axis}");
                    assert_eq!(
                        *cuts.last().unwrap(),
                        n as u32,
                        "n={n} {rows}x{cols} {axis}"
                    );
                    assert!(
                        cuts.windows(2).all(|w| w[0] < w[1]),
                        "n={n} {rows}x{cols} {axis}: non-monotone/empty {cuts:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn exhaustive_grids_every_edge_block_owned_exactly_once() {
    for n in 1..=64usize {
        let g = random_graph(n, 1000 + n as u64);
        for rows in 1..=8.min(n as u32) {
            for cols in 1..=8.min(n as u32) {
                let p2 = Partition2D::new(&g, rows, cols);
                // How many processor-row (resp. -column) ranges contain
                // each vertex; exactly-one per axis makes every (u, w)
                // block owned by exactly rowcount·colcount = 1 processor.
                for u in 0..n as u32 {
                    let owning_rows = (0..rows)
                        .filter(|&i| {
                            let (lo, hi) = p2.row_range(i);
                            lo <= u && u < hi
                        })
                        .count();
                    let owning_cols = (0..cols)
                        .filter(|&j| {
                            let (lo, hi) = p2.col_range(j);
                            lo <= u && u < hi
                        })
                        .count();
                    assert_eq!(owning_rows, 1, "n={n} {rows}x{cols} u={u}");
                    assert_eq!(owning_cols, 1, "n={n} {rows}x{cols} w={u}");
                }
            }
        }
    }
}

#[test]
fn exhaustive_grids_owner_of_edge_consistent_with_ranges() {
    for n in 1..=64usize {
        let g = random_graph(n, 2000 + n as u64);
        for rows in 1..=8.min(n as u32) {
            for cols in 1..=8.min(n as u32) {
                let p2 = Partition2D::new(&g, rows, cols);
                for u in 0..n as u32 {
                    for w in 0..n as u32 {
                        let rank = p2.owner_of_edge(u, w);
                        let (i, j) = p2.coords(rank);
                        assert_eq!(rank, p2.rank(i, j));
                        assert_eq!(i, p2.row_of(u), "n={n} {rows}x{cols} u={u}");
                        assert_eq!(j, p2.col_of(w), "n={n} {rows}x{cols} w={w}");
                        let (rlo, rhi) = p2.row_range(i);
                        let (clo, chi) = p2.col_range(j);
                        assert!(rlo <= u && u < rhi);
                        assert!(clo <= w && w < chi);
                    }
                }
            }
        }
    }
}

#[test]
fn exhaustive_grids_block_slabs_partition_the_edge_set() {
    for n in 1..=64usize {
        let g = random_graph(n, 3000 + n as u64);
        for rows in 1..=8.min(n as u32) {
            for cols in 1..=8.min(n as u32) {
                let p2 = Partition2D::new(&g, rows, cols);
                let slabs = p2.block_slabs(&g);
                assert_eq!(slabs.len(), (rows * cols) as usize);
                let total: u64 = slabs.iter().map(|s| s.num_edges()).sum();
                assert_eq!(total, g.num_edges(), "n={n} {rows}x{cols}");
                // Each edge lands in the slab `owner_of_edge` names.
                for u in 0..n as u32 {
                    for &w in g.neighbors(u) {
                        let rank = p2.owner_of_edge(u, w) as usize;
                        assert!(
                            slabs[rank].neighbors_global(u).contains(&w),
                            "n={n} {rows}x{cols} edge ({u},{w}) missing from block"
                        );
                    }
                }
            }
        }
    }
}

/// The column cuts are *in-edge* balanced (not vertex-balanced): over the
/// exhaustive grid window, every column's in-edge load must stay within
/// one vertex's in-degree of the ideal `total/cols` share — the greedy
/// prefix bound — and the per-column loads must tile the arc set.
#[test]
fn exhaustive_grids_col_cuts_are_in_edge_balanced() {
    for n in 1..=64usize {
        let g = random_graph(n, 4000 + n as u64);
        let mut in_deg = vec![0u64; n];
        for u in 0..n as u32 {
            for &w in g.neighbors(u) {
                in_deg[w as usize] += 1;
            }
        }
        let max_in = in_deg.iter().copied().max().unwrap_or(0);
        for rows in 1..=8.min(n as u32) {
            for cols in 1..=8.min(n as u32) {
                let p2 = Partition2D::new(&g, rows, cols);
                let per = p2.col_in_edges(&g);
                assert_eq!(per.len(), cols as usize);
                assert_eq!(
                    per.iter().sum::<u64>(),
                    g.num_edges(),
                    "n={n} {rows}x{cols}: columns tile the arcs"
                );
                // Greedy prefix bound: a column overshoots the ideal share
                // by at most the in-degree of its boundary vertex (modulo
                // the forced non-empty-range clamping, which only *shrinks*
                // ranges). The last column additionally absorbs rounding.
                let ideal = g.num_edges() as f64 / cols as f64;
                for (j, &load) in per.iter().enumerate() {
                    assert!(
                        (load as f64) <= 2.0 * ideal + max_in as f64,
                        "n={n} {rows}x{cols} col {j}: load {load} vs ideal {ideal}"
                    );
                }
            }
        }
    }
}

/// On an in-degree-skewed graph the edge-balanced column cuts isolate the
/// hub instead of packing it with a vertex-balanced share of leaves — the
/// processor-column load regression this cut policy fixes.
#[test]
fn skewed_graph_hub_column_is_not_overloaded() {
    let mut b = GraphBuilder::new(512);
    // Hub 0 touches everyone; a sparse ring keeps the rest connected.
    for v in 1..512u32 {
        b.add_edge(0, v);
        b.add_edge(v, (v % 511) + 1);
    }
    let g = b.build_undirected().0;
    let p2 = Partition2D::new(&g, 2, 4);
    let imb = p2.col_imbalance(&g);
    assert!(imb < 1.5, "edge-balanced column imbalance {imb}");
    // The hub's column must be far narrower than the vertex-balanced
    // 512/4 = 128 vertices.
    let (lo, hi) = p2.col_range(0);
    assert_eq!(lo, 0);
    assert!(hi < 64, "hub column spans {hi} vertices");
}

/// Larger ragged vertex counts (beyond the exhaustive window) keep the
/// invariants, property-style.
#[test]
fn ragged_large_counts_keep_invariants() {
    use butterfly_bfs::util::propcheck::{forall, gen, Config};
    forall(Config::cases(40), "2d partition invariants at scale", |rng| {
        let n = gen::usize_in(rng, 65, 3000);
        let rows = gen::usize_in(rng, 1, 8) as u32;
        let cols = gen::usize_in(rng, 1, 8) as u32;
        let g = random_graph(n, rng.next_u64());
        let p2 = Partition2D::new(&g, rows, cols);
        let edges_total: u64 = p2.block_edges(&g).iter().sum();
        let ok = edges_total == g.num_edges()
            && (0..n as u32).all(|v| {
                let i = p2.row_of(v);
                let j = p2.col_of(v);
                let (rlo, rhi) = p2.row_range(i);
                let (clo, chi) = p2.col_range(j);
                rlo <= v && v < rhi && clo <= v && v < chi
            });
        (ok, format!("n={n} grid={rows}x{cols}"))
    });
}
