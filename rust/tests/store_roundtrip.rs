//! `.bbfs` v2 store integration tests: encode→load round-trips across
//! the generator suite (including degenerate graphs), relabeled stores
//! executing bit-identically to in-memory plans in both partition modes,
//! plan warm-starts that decode nothing up front yet answer identically
//! to cold builds, and a corrupt/fuzz corpus that must never panic.

use butterfly_bfs::coordinator::{EngineConfig, PartitionMode, TraversalPlan};
use butterfly_bfs::graph::csr::{Csr, VertexId};
use butterfly_bfs::graph::gen::structured::{binary_tree, grid2d, path, star};
use butterfly_bfs::graph::gen::suite::table1_suite;
use butterfly_bfs::graph::gen::urand::uniform_random;
use butterfly_bfs::graph::store::{
    encode_store, v1_snapshot_bytes, write_store, GraphStore, StoreWriteOptions,
};
use butterfly_bfs::partition::relabel::apply_relabeling;
use std::sync::Arc;

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("bbfs-store-test-{}-{name}", std::process::id()));
    p
}

fn roundtrip(g: &Csr, opts: StoreWriteOptions) -> (Csr, GraphStore) {
    let relabel = opts.relabel;
    let enc = encode_store(g, opts).unwrap();
    let store = GraphStore::open_bytes(enc.bytes).unwrap();
    assert_eq!(store.num_vertices(), g.num_vertices());
    assert_eq!(store.num_edges(), g.num_edges());
    assert_eq!(store.is_relabeled(), relabel && g.num_vertices() > 0);
    let decoded = store.to_csr().unwrap();
    (decoded, store)
}

/// `load(convert(g)) == g` across the whole generator suite, plain and
/// relabeled (relabeled stores decode to the permuted graph, which maps
/// back to the original exactly).
#[test]
fn store_roundtrips_generator_suite() {
    let mut graphs: Vec<(String, Csr)> = table1_suite()
        .iter()
        .map(|spec| (spec.name.to_string(), spec.generate_scaled(-10)))
        .collect();
    graphs.push(("path".into(), path(257)));
    graphs.push(("star".into(), star(300)));
    graphs.push(("grid".into(), grid2d(17, 13)));
    graphs.push(("tree".into(), binary_tree(200)));
    for (name, g) in &graphs {
        let (decoded, _) = roundtrip(g, StoreWriteOptions::default());
        assert_eq!(&decoded, g, "{name}: plain store round-trip");

        let enc = encode_store(g, StoreWriteOptions { relabel: true, ..Default::default() })
            .unwrap();
        let r = enc.relabeling.as_ref().unwrap();
        let store = GraphStore::open_bytes(enc.bytes).unwrap();
        let decoded = store.to_csr().unwrap();
        assert_eq!(decoded, apply_relabeling(g, r), "{name}: relabeled store holds P(g)");
        // The stored permutation matches the writer's.
        let stored = store.relabeling().unwrap();
        assert_eq!(stored.new_id, r.new_id, "{name}: stored new_id");
        assert_eq!(stored.old_id, r.old_id, "{name}: stored old_id");
    }
}

/// Degenerate inputs round-trip too: the empty graph, a single vertex,
/// isolated vertices, and duplicate (multi-)edges, across block sizes
/// that force partial and many-block layouts.
#[test]
fn store_roundtrips_degenerate_graphs() {
    let cases: Vec<(&str, Csr)> = vec![
        ("empty", Csr::from_edges(0, &[])),
        ("single-vertex", Csr::from_edges(1, &[])),
        ("self-loop", Csr::from_edges(1, &[(0, 0)])),
        ("isolated", Csr::from_edges(5, &[(2, 4), (4, 2)])),
        (
            "duplicate-edges",
            Csr::from_edges(4, &[(0, 1), (0, 1), (0, 1), (1, 0), (3, 2), (3, 2)]),
        ),
    ];
    for (name, g) in &cases {
        for block_size in [1u32, 2, 3, 1024] {
            let opts = StoreWriteOptions { relabel: false, block_size };
            let (decoded, store) = roundtrip(g, opts);
            assert_eq!(&decoded, g, "{name} bs={block_size}");
            assert_eq!(store.block_size(), block_size);
        }
    }
}

/// File-backed loads agree with the in-memory image: `open` (pread) and
/// `open_mmap` return identical graphs and identical fingerprints.
#[test]
fn file_and_mmap_loads_match_bytes() {
    let (g, _) = uniform_random(700, 6, 41);
    let p = tmp("file-mmap.bbfs");
    let enc = write_store(&g, &p, StoreWriteOptions::default()).unwrap();
    let mem = GraphStore::open_bytes(enc.bytes).unwrap();
    let file = GraphStore::open(&p).unwrap();
    assert_eq!(file.fingerprint(), mem.fingerprint());
    assert_eq!(file.to_csr().unwrap(), g);
    let mapped = GraphStore::open_mmap(&p).unwrap();
    assert_eq!(mapped.fingerprint(), mem.fingerprint());
    assert_eq!(mapped.to_csr().unwrap(), g);
    std::fs::remove_file(&p).ok();
}

/// The headline size claim, checked in-repo: on the web-like suite graph
/// the v2 container is at least 2× smaller than the v1 raw-CSR snapshot.
#[test]
fn v2_at_least_twice_smaller_than_v1_on_weblike() {
    let spec = table1_suite().into_iter().find(|s| s.name == "web-like").unwrap();
    let g = spec.generate_scaled(-8);
    let enc = encode_store(&g, StoreWriteOptions::default()).unwrap();
    let v1 = v1_snapshot_bytes(&g) as f64;
    let v2 = enc.bytes.len() as f64;
    assert!(
        v1 / v2 >= 2.0,
        "compression ratio {:.2} below the 2x floor (v1={v1} v2={v2})",
        v1 / v2
    );
}

/// A plan built from a relabeled store returns BFS distances
/// bit-identical to an in-memory plan over the original graph, in both
/// 1D and 2D partition modes (distances unmapped via the stored
/// permutation).
#[test]
fn relabeled_store_plans_bit_identical_to_in_memory() {
    let (g, _) = uniform_random(900, 6, 59);
    let enc =
        encode_store(&g, StoreWriteOptions { relabel: true, ..Default::default() }).unwrap();
    let store = Arc::new(GraphStore::open_bytes(enc.bytes).unwrap());
    let configs = [
        ("1d", EngineConfig::dgx2(4, 2)),
        (
            "2d",
            EngineConfig {
                partition: PartitionMode::TwoD { rows: 2, cols: 2 },
                ..EngineConfig::dgx2(4, 1)
            },
        ),
    ];
    for (mode, cfg) in configs {
        let reference = TraversalPlan::build(&g, cfg.clone()).unwrap();
        let plan = TraversalPlan::build_from_store(Arc::clone(&store), cfg).unwrap();
        plan.materialize().unwrap();
        let r = plan.relabeling().expect("relabeled store plan carries the permutation");
        for root in [0 as VertexId, 13, 444, 899] {
            let want = reference.session().run(root).unwrap().dist().to_vec();
            let exec_root = r.new_id[root as usize];
            let got_new = plan.session().run(exec_root).unwrap().dist().to_vec();
            let got = r.unmap_dist(&got_new);
            assert_eq!(got, want, "{mode} root {root}: distances diverge");
        }
    }
}

/// Warm-start: `save_cache` then `load_cache` against a fresh store
/// handle decodes **zero** degree entries and **zero** adjacency edges at
/// load time, and after materializing answers bit-identically to the
/// cold build — in both partition modes.
#[test]
fn warm_start_decodes_nothing_up_front_and_matches_cold() {
    let (g, _) = uniform_random(800, 5, 67);
    let p = tmp("warm.bbfs");
    write_store(&g, &p, StoreWriteOptions::default()).unwrap();
    let configs = [
        ("1d", EngineConfig::dgx2(4, 2)),
        (
            "2d",
            EngineConfig {
                partition: PartitionMode::TwoD { rows: 2, cols: 2 },
                ..EngineConfig::dgx2(4, 1)
            },
        ),
    ];
    for (mode, cfg) in configs {
        let cache = tmp(&format!("warm-{mode}.plan.json"));
        let cold_store = Arc::new(GraphStore::open(&p).unwrap());
        let cold =
            TraversalPlan::build_from_store(Arc::clone(&cold_store), cfg.clone()).unwrap();
        cold.materialize().unwrap();
        cold.save_cache(&cache).unwrap();

        let warm_store = Arc::new(GraphStore::open(&p).unwrap());
        let warm =
            TraversalPlan::load_cache(Arc::clone(&warm_store), cfg.clone(), &cache).unwrap();
        let at_load = warm_store.counters();
        assert_eq!(
            (at_load.degree_entries_decoded, at_load.edges_decoded),
            (0, 0),
            "{mode}: warm-start load must not decode anything"
        );
        warm.materialize().unwrap();
        let after = warm_store.counters();
        assert!(after.edges_decoded > 0, "{mode}: materialize decodes the slabs");
        for root in [0 as VertexId, 7, 399, 799] {
            assert_eq!(
                warm.session().run(root).unwrap().dist(),
                cold.session().run(root).unwrap().dist(),
                "{mode} root {root}: warm answers diverge from cold"
            );
        }

        // A mismatched config is a typed fingerprint error, not silence:
        // warming a 16-node cache with an 8-node config must fail.
        let other = EngineConfig { num_nodes: cfg.num_nodes * 2, ..cfg.clone() };
        assert!(
            TraversalPlan::load_cache(Arc::clone(&warm_store), other, &cache).is_err(),
            "{mode}: node-count mismatch must be rejected"
        );
        std::fs::remove_file(&cache).ok();
    }
    std::fs::remove_file(&p).ok();
}

// ---------- hostile inputs ----------

/// Header/index/perm field offsets for targeted corruption (see the
/// layout table in `graph::store`).
const OFF_VERSION: usize = 8;
const OFF_FLAGS: usize = 12;
const OFF_N: usize = 16;
const OFF_INDEX: usize = 72;

fn open_and_decode(bytes: Vec<u8>) -> Result<Csr, butterfly_bfs::graph::store::StoreError> {
    let store = GraphStore::open_bytes(bytes)?;
    store.degree_prefix()?;
    store.to_csr()
}

/// Targeted v2 corruption corpus: every mutation must surface as a typed
/// `StoreError`, never a panic or a wrong graph.
#[test]
fn v2_corrupt_corpus_returns_typed_errors() {
    let (g, _) = uniform_random(300, 5, 71);
    let enc = encode_store(
        &g,
        StoreWriteOptions { relabel: true, block_size: 64 },
    )
    .unwrap();
    let base = enc.bytes;

    let put_u32 = |img: &mut [u8], at: usize, v: u32| {
        img[at..at + 4].copy_from_slice(&v.to_le_bytes())
    };
    let put_u64 = |img: &mut [u8], at: usize, v: u64| {
        img[at..at + 8].copy_from_slice(&v.to_le_bytes())
    };

    let mut cases: Vec<(&str, Vec<u8>)> = Vec::new();

    let mut img = base.clone();
    img[..8].copy_from_slice(b"WRONGMAG");
    cases.push(("wrong magic", img));

    let mut img = base.clone();
    put_u32(&mut img, OFF_VERSION, 3);
    cases.push(("future version", img));

    let mut img = base.clone();
    put_u32(&mut img, OFF_FLAGS, 0xFFFF_FFFF);
    cases.push(("unknown flags", img));

    let mut img = base.clone();
    put_u64(&mut img, OFF_N, u64::from(u32::MAX) + 7);
    cases.push(("n past u32", img));

    let mut img = base.clone();
    put_u64(&mut img, OFF_N, 301);
    cases.push(("n inflated", img));

    let mut img = base.clone();
    img.truncate(base.len() - 1);
    cases.push(("truncated tail", img));

    let mut img = base.clone();
    img.extend_from_slice(&[0xAB; 3]);
    cases.push(("trailing garbage", img));

    let mut img = base.clone();
    img.truncate(40);
    cases.push(("header only", img));

    // Index entry 1: non-monotone data_start.
    let mut img = base.clone();
    put_u64(&mut img, OFF_INDEX + 16, u64::MAX);
    cases.push(("non-monotone index", img));

    // Index entry 1: first_edge beyond m.
    let mut img = base.clone();
    put_u64(&mut img, OFF_INDEX + 24, g.num_edges() + 99);
    cases.push(("index first_edge past m", img));

    // Sentinel edge count off by one (degree sums can no longer match).
    let n_blocks = (300u64).div_ceil(64) as usize;
    let sentinel = OFF_INDEX + 16 * n_blocks;
    let mut img = base.clone();
    put_u64(&mut img, sentinel + 8, g.num_edges() - 1);
    cases.push(("bad sentinel", img));

    // Permutation: duplicate entry (no longer a bijection).
    let perm_off = OFF_INDEX + 16 * (n_blocks + 1);
    let mut img = base.clone();
    let first = u32::from_le_bytes(base[perm_off..perm_off + 4].try_into().unwrap());
    put_u32(&mut img, perm_off + 4, first);
    cases.push(("duplicate perm entry", img));

    // Permutation: out-of-range id.
    let mut img = base.clone();
    put_u32(&mut img, perm_off, 300);
    cases.push(("perm id out of range", img));

    // Adjacency data: force a 10-byte all-continuation varint at the
    // start of the first block's degree stream (overlong/overflow).
    let data_off =
        u64::from_le_bytes(base[56..64].try_into().unwrap()) as usize;
    let mut img = base.clone();
    for b in img[data_off..data_off + 10].iter_mut() {
        *b = 0x80;
    }
    cases.push(("overflowing varint", img));

    for (name, img) in cases {
        assert!(open_and_decode(img).is_err(), "{name}: must be a typed error");
    }

    // The unmutated base still decodes to the permuted graph.
    assert!(open_and_decode(base).is_ok());
}

/// Bit-flip fuzz: flipping any single byte of a small store image may be
/// rejected or (for dead bytes like alignment padding) still decode, but
/// it must never panic — the loader's whole contract under hostile input.
#[test]
fn v2_single_byte_flips_never_panic() {
    let g = Csr::from_edges(
        40,
        &(0..40u32).flat_map(|v| [(v, (v + 1) % 40), ((v + 1) % 40, v)]).collect::<Vec<_>>(),
    );
    let enc = encode_store(&g, StoreWriteOptions { relabel: true, block_size: 8 }).unwrap();
    let base = enc.bytes;
    for at in 0..base.len() {
        let mut img = base.clone();
        img[at] ^= 0xFF;
        // Ok or Err are both acceptable; a panic fails the test run.
        let _ = open_and_decode(img);
    }
}
