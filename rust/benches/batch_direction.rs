//! Batched direction ablation: `run_batch` under topdown / bottomup /
//! diropt, head-to-head at p ∈ {16, 64} simulated nodes on the RMAT and
//! web-like suite graphs — the experiment behind the batched
//! direction-optimizing path (Beamer's switch composed with the MS-BFS
//! lane-mask bottom-up formulation of Then et al.).
//!
//! Reported per (graph, p, direction): levels and how many ran bottom-up,
//! edges inspected (the quantity direction optimization shrinks; ratio vs
//! top-down in the last column), exchange bytes, and simulated DGX-2
//! time. Distances are asserted identical across directions before any
//! number is printed.
//!
//! Run: `cargo bench --bench batch_direction`
//! (`BBFS_SCALE_DELTA=n` rescales the graphs; `BBFS_BENCH_PROFILE=full`
//! uses the larger defaults.)

use butterfly_bfs::bfs::msbfs::sample_batch_roots;
use butterfly_bfs::coordinator::config::DirectionMode;
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::table::{count, f2, ms, Table};

fn main() {
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(match std::env::var("BBFS_BENCH_PROFILE").as_deref() {
            Ok("full") => -4,
            _ => -6,
        });

    for name in ["kron-like", "webbase-like"] {
        let spec = table1_suite().into_iter().find(|s| s.name == name).unwrap();
        let g = spec.generate_scaled(scale_delta);
        let roots = sample_batch_roots(&g, 64, 7);
        println!(
            "== batch_direction on {} (|V|={}, |E|={}), 64 roots ==",
            spec.name,
            count(g.num_vertices() as u64),
            count(g.num_edges()),
        );
        let mut t = Table::new(&[
            "p",
            "direction",
            "levels",
            "bu levels",
            "edges inspected",
            "bytes",
            "sim ms",
            "edges vs topdown",
        ]);
        for p in [16usize, 64] {
            let mut td_edges = 0u64;
            let mut td_dist: Option<Vec<Vec<u32>>> = None;
            for (label, direction) in [
                ("topdown", DirectionMode::TopDown),
                ("bottomup", DirectionMode::BottomUp),
                ("diropt", DirectionMode::diropt()),
            ] {
                let cfg = EngineConfig { direction, ..EngineConfig::dgx2(p, 4) };
                let plan = TraversalPlan::build(&g, cfg).expect("valid plan");
                let mut session = plan.session();
                let b = session.run_batch(&roots).expect("roots in range");
                session.assert_batch_agreement().expect("node agreement");
                // Distances must not depend on the direction policy.
                let dists: Vec<Vec<u32>> =
                    (0..roots.len()).map(|l| b.dist(l).to_vec()).collect();
                match &td_dist {
                    None => td_dist = Some(dists),
                    Some(want) => assert_eq!(want, &dists, "{label} diverged"),
                }
                let m = b.metrics();
                if direction == DirectionMode::TopDown {
                    td_edges = m.edges_examined();
                }
                t.row(vec![
                    p.to_string(),
                    label.to_string(),
                    m.depth().to_string(),
                    m.bottom_up_levels().to_string(),
                    count(m.edges_examined()),
                    count(m.bytes()),
                    ms(m.sim_seconds()),
                    f2(m.edges_examined() as f64 / td_edges.max(1) as f64),
                ]);
            }
        }
        println!("{}", t.render());
    }
    println!(
        "note: the committed perf trajectory for the fixed protocol configs \
         lives in BENCH_engine.json (butterfly-bfs bench-protocol --check)."
    );
}
