//! Regenerates **Fig. 3** (strong scaling) and the **§5 speedup /
//! utilization** list: simulated execution time vs node count for fanout
//! 1 and fanout 4, per suite graph, plus Speedup/Ideal/Utilization
//! derived exactly as the paper defines them.
//!
//! Expected shape (paper): steady improvement with node count for the big
//! small-world graphs; a visible fanout-1 regression from 8 → 9 nodes;
//! webbase-like nearly flat (no parallelism); utilization ~70–95 %.
//!
//! Run: `cargo bench --bench fig3_strong_scaling`

use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::experiments::scaling_sweep;
use butterfly_bfs::harness::roots::RootProtocol;
use butterfly_bfs::harness::table::{f2, ms, Table};
use butterfly_bfs::util::json::Json;
use butterfly_bfs::util::stats::scaling_utilization;

fn main() {
    let proto = RootProtocol::from_env();
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    // The paper sweeps from each graph's minimal GPU count to 16; at our
    // scale every graph fits everywhere, so we sweep the same axis and
    // include 9 to expose the fanout-1 bottleneck.
    let node_counts = [2usize, 4, 8, 9, 12, 16];
    let fanouts = [1u32, 4];
    println!(
        "== Fig 3: strong scaling (nodes x fanout, {} roots trim {}) ==\n",
        proto.num_roots, proto.trim
    );
    let mut json_graphs = Vec::new();
    for spec in table1_suite() {
        let g = spec.generate_scaled(scale_delta);
        let pts = scaling_sweep(&g, &node_counts, &fanouts, &proto);
        let mut table = Table::new(&["nodes", "fanout-1 ms", "fanout-4 ms", "f1/f4"]);
        for &n in &node_counts {
            let t1 = pts.iter().find(|p| p.nodes == n && p.fanout == 1).unwrap();
            let t4 = pts.iter().find(|p| p.nodes == n && p.fanout == 4).unwrap();
            table.row(vec![
                n.to_string(),
                ms(t1.sim_time),
                ms(t4.sim_time),
                f2(t1.sim_time / t4.sim_time),
            ]);
        }
        println!("-- {} (analog of {}) --", spec.name, spec.paper_graph);
        println!("{}", table.render());
        // §5 Speedup Analysis (fanout 4). The paper computes speedup from
        // each graph's *minimal feasible* GPU count (500 M edges/GPU ⇒ 8
        // for the big rows) to 16, so Ideal is ~2; we report that window
        // plus the full 2→16 sweep for context.
        let at = |n: usize| {
            pts.iter()
                .find(|p| p.nodes == n && p.fanout == 4)
                .unwrap()
                .sim_time
        };
        let u_paper = scaling_utilization(at(8), 8, at(16), 16);
        let u_full = scaling_utilization(
            at(node_counts[0]),
            node_counts[0],
            at(*node_counts.last().unwrap()),
            *node_counts.last().unwrap(),
        );
        println!(
            "   paper window 8->16: speedup {:.2}, ideal {:.2}, utilization {:.1}%",
            u_paper.speedup,
            u_paper.ideal,
            u_paper.utilization * 100.0
        );
        println!(
            "   full sweep {}->{}: speedup {:.2}, ideal {:.2}, utilization {:.1}%\n",
            node_counts[0],
            node_counts.last().unwrap(),
            u_full.speedup,
            u_full.ideal,
            u_full.utilization * 100.0
        );
        let u = u_paper;
        json_graphs.push(Json::obj(vec![
            ("graph", Json::s(spec.name)),
            (
                "points",
                Json::Arr(
                    pts.iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("nodes", Json::u(p.nodes as u64)),
                                ("fanout", Json::u(p.fanout as u64)),
                                ("sim_s", Json::n(p.sim_time)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("speedup", Json::n(u.speedup)),
            ("utilization", Json::n(u.utilization)),
        ]));
    }
    std::fs::create_dir_all("target/bench-results").ok();
    std::fs::write(
        "target/bench-results/fig3.json",
        Json::obj(vec![("fig3", Json::Arr(json_graphs))]).render(),
    )
    .ok();
    println!("json: target/bench-results/fig3.json");
}
