//! Regenerates **Table 1**: CPU (direction-optimizing and top-down) vs
//! simulated DGX-2 ButterFly BFS across the nine-graph analog suite, with
//! the paper's root protocol (100 roots, trim 25/25 under
//! `BBFS_BENCH_PROFILE=full`; a scaled-down protocol otherwise).
//!
//! Expected shape (paper): DGX2/CPU-DO in 2×–22×, DGX2/CPU-TD in 2×–233×
//! with the kron row the extreme; CPU DO/TD largest on kron/urand
//! small-world rows, near 1 on the high-diameter web rows.
//!
//! Run: `cargo bench --bench table1_cpu_vs_dgx2`
//! Full profile: `BBFS_BENCH_PROFILE=full cargo bench --bench table1_cpu_vs_dgx2`

use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::experiments::table1_row;
use butterfly_bfs::harness::roots::RootProtocol;
use butterfly_bfs::harness::table::{count, f2, ms, Table};
use butterfly_bfs::util::json::Json;

fn main() {
    let proto = RootProtocol::from_env();
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    println!(
        "== Table 1 (analog suite, scale_delta={scale_delta}, {} roots trim {}) ==\n",
        proto.num_roots, proto.trim
    );
    let mut table = Table::new(&[
        "graph",
        "paper",
        "|V|",
        "|E|",
        "diam",
        "CPU-DO ms",
        "CPU-TD ms",
        "DO/TD",
        "DGX2 ms",
        "DGX2 GTEPS",
        "DGX2/CPU-DO",
        "DGX2/CPU-TD",
    ]);
    let mut rows_json = Vec::new();
    for spec in table1_suite() {
        let g = spec.generate_scaled(scale_delta);
        let row = table1_row(&spec, &g, &proto);
        table.row(vec![
            row.name.into(),
            row.paper_graph.into(),
            count(row.vertices),
            count(row.edges),
            row.diameter.to_string(),
            ms(row.cpu_do_time),
            ms(row.cpu_td_time),
            f2(row.cpu_do_over_td()),
            ms(row.dgx2_time),
            f2(row.dgx2_gteps),
            f2(row.dgx2_over_cpu_do()),
            f2(row.dgx2_over_cpu_td()),
        ]);
        rows_json.push(Json::obj(vec![
            ("graph", Json::s(row.name)),
            ("paper_graph", Json::s(row.paper_graph)),
            ("vertices", Json::u(row.vertices)),
            ("edges", Json::u(row.edges)),
            ("diameter", Json::u(row.diameter as u64)),
            ("cpu_do_s", Json::n(row.cpu_do_time)),
            ("cpu_td_s", Json::n(row.cpu_td_time)),
            ("dgx2_s", Json::n(row.dgx2_time)),
            ("dgx2_gteps", Json::n(row.dgx2_gteps)),
            ("speedup_do_over_td", Json::n(row.cpu_do_over_td())),
            ("speedup_dgx2_over_do", Json::n(row.dgx2_over_cpu_do())),
            ("speedup_dgx2_over_td", Json::n(row.dgx2_over_cpu_td())),
        ]));
        eprintln!("  finished {}", spec.name);
    }
    println!("{}", table.render());
    let out = Json::obj(vec![("table1", Json::Arr(rows_json))]).render();
    std::fs::create_dir_all("target/bench-results").ok();
    std::fs::write("target/bench-results/table1.json", &out).ok();
    println!("json: target/bench-results/table1.json");
}
