//! Batch-width ablation: one wide batch (W ∈ {1, 2, 4, 8} lane words)
//! against the same roots executed as 64-root single-word chunks, in 1D
//! (butterfly f4) and 2D (fold/expand) — the experiment behind the
//! const-generic wide lane masks.
//!
//! Reported per (mode, width): the lane words and sparse entry bytes of
//! the wire format, sync rounds and exchange bytes for the wide batch vs
//! its chunks, and the simulated DGX-2 time per root. Distances are
//! asserted bit-identical between the wide batch and its chunks before
//! any number is printed — the chunked run *is* the correctness oracle.
//!
//! The structural claim on display: sync rounds per level are
//! width-invariant (one exchange serves the whole batch), so rounds per
//! root fall ~linearly with width, while the cohort-factored negotiated
//! encoding keeps total bytes at or below the chunked cost.
//!
//! A second table ablates the mask *kernel* (scalar vs chunked, LRB on
//! vs off) per partition mode at a fixed width, bottom-up — wallclock
//! next to the deterministic work counters the protocol commits.
//! `--update` records those wallclock rows into `BENCH_engine.json`'s
//! `kernel_ablation_measured` subtree (excluded from the freshness
//! compare, like the serve one).
//!
//! Run: `cargo bench --bench batch_width [-- --update]`
//! (`BBFS_SCALE_DELTA=n` rescales the graph; `BBFS_BENCH_PROFILE=full`
//! uses the larger default.)

use butterfly_bfs::bfs::msbfs::sample_batch_roots;
use butterfly_bfs::coordinator::config::DirectionMode;
use butterfly_bfs::coordinator::{
    BatchWidth, EngineConfig, KernelVariant, PartitionMode, TraversalPlan,
};
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::table::{count, f2, ms, Table};
use butterfly_bfs::util::json::Json;

fn main() {
    let update = std::env::args().any(|a| a == "--update");
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(match std::env::var("BBFS_BENCH_PROFILE").as_deref() {
            Ok("full") => -5,
            _ => -7,
        });
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "kron-like")
        .unwrap();
    let g = spec.generate_scaled(scale_delta);
    println!(
        "== batch_width on {} (|V|={}, |E|={}) ==",
        spec.name,
        count(g.num_vertices() as u64),
        count(g.num_edges()),
    );
    let mut t = Table::new(&[
        "mode",
        "width",
        "W",
        "entry B",
        "rounds",
        "rounds chunked",
        "bytes",
        "bytes chunked",
        "sim ms/root",
        "chunked ms/root",
        "bytes vs chunked",
    ]);
    for mode in ["1d", "2d"] {
        for width in [64usize, 128, 256, 512] {
            let roots = sample_batch_roots(&g, width, 7);
            let base = match mode {
                "1d" => EngineConfig::dgx2(16, 4),
                _ => EngineConfig {
                    partition: PartitionMode::TwoD { rows: 4, cols: 4 },
                    ..EngineConfig::dgx2(16, 1)
                },
            };
            let cfg = EngineConfig {
                batch_width: BatchWidth::for_lanes(width)
                    .expect("bench widths are within the lane limit"),
                ..base.clone()
            };
            let plan = TraversalPlan::build(&g, cfg).expect("valid plan");
            let mut session = plan.session();
            let wide = session.run_batch(&roots).expect("roots in range");
            session.assert_batch_agreement().expect("node agreement");

            // Chunked baseline through one pooled single-word session —
            // also the oracle: every lane must match bit for bit.
            let mut chunked = TraversalPlan::build(&g, base)
                .expect("valid plan")
                .session();
            let (mut c_rounds, mut c_bytes, mut c_sim) = (0u64, 0u64, 0f64);
            for (ci, chunk) in roots.chunks(64).enumerate() {
                let cb = chunked.run_batch(chunk).expect("roots in range");
                for (lane, _) in chunk.iter().enumerate() {
                    assert_eq!(
                        cb.dist(lane),
                        wide.dist(ci * 64 + lane),
                        "{mode} width {width} chunk {ci} lane {lane}"
                    );
                }
                c_rounds += cb.metrics().sync_rounds;
                c_bytes += cb.metrics().bytes();
                c_sim += cb.metrics().sim_seconds();
            }
            let m = wide.metrics();
            t.row(vec![
                mode.to_string(),
                width.to_string(),
                m.lane_words.to_string(),
                m.entry_bytes().to_string(),
                m.sync_rounds.to_string(),
                c_rounds.to_string(),
                count(m.bytes()),
                count(c_bytes),
                ms(m.sim_seconds() / width as f64),
                ms(c_sim / width as f64),
                f2(m.bytes() as f64 / c_bytes.max(1) as f64),
            ]);
            assert!(
                m.sync_rounds <= c_rounds,
                "{mode} width {width}: wide rounds exceed chunked"
            );
        }
    }
    println!("{}", t.render());

    // ---- Kernel ablation: scalar vs chunked (and LRB off) per mode. ----
    const KERNEL_WIDTH: usize = 256;
    let mut kt = Table::new(&[
        "mode",
        "kernel",
        "lrb",
        "wall ms",
        "words touched",
        "words skipped",
        "dispatches",
        "max work",
    ]);
    let mut measured_rows: Vec<Json> = Vec::new();
    for mode in ["1d", "2d", "hier"] {
        let roots = sample_batch_roots(&g, KERNEL_WIDTH, 7);
        let mut oracle: Option<Vec<Vec<u32>>> = None;
        for (kernel, use_lrb) in [
            (KernelVariant::Scalar, true),
            (KernelVariant::Chunked, true),
            (KernelVariant::Chunked, false),
        ] {
            let base = match mode {
                "1d" => EngineConfig::dgx2(16, 4),
                "2d" => EngineConfig {
                    partition: PartitionMode::TwoD { rows: 4, cols: 4 },
                    ..EngineConfig::dgx2(16, 1)
                },
                _ => EngineConfig::dgx2_cluster_hier(4, 4, 4),
            };
            let cfg = EngineConfig {
                direction: DirectionMode::BottomUp,
                kernel,
                use_lrb,
                batch_width: BatchWidth::for_lanes(KERNEL_WIDTH)
                    .expect("bench widths are within the lane limit"),
                ..base
            };
            let mut session = TraversalPlan::build(&g, cfg).expect("valid plan").session();
            let b = session.run_batch(&roots).expect("roots in range");
            // Bit-identity oracle: every variant must agree with the
            // first one, lane for lane, before any number is printed.
            let dists: Vec<Vec<u32>> =
                (0..KERNEL_WIDTH).map(|lane| b.dist(lane).to_vec()).collect();
            match &oracle {
                None => oracle = Some(dists),
                Some(o) => assert_eq!(
                    o, &dists,
                    "{mode}: kernel {} lrb={use_lrb} changed distances",
                    kernel.name()
                ),
            }
            let m = b.metrics();
            kt.row(vec![
                mode.to_string(),
                kernel.name().to_string(),
                use_lrb.to_string(),
                ms(m.wall_seconds),
                count(m.words_touched()),
                count(m.words_skipped()),
                count(m.dispatches()),
                count(m.dispatch_max_work()),
            ]);
            measured_rows.push(Json::obj(vec![
                ("mode", Json::s(mode)),
                ("width", Json::u(KERNEL_WIDTH as u64)),
                ("kernel", Json::s(kernel.name())),
                ("lrb", Json::Bool(use_lrb)),
                ("wall_seconds", Json::n(m.wall_seconds)),
                ("words_touched", Json::u(m.words_touched())),
                ("words_skipped", Json::u(m.words_skipped())),
                ("dispatches", Json::u(m.dispatches())),
                ("dispatch_max_work", Json::u(m.dispatch_max_work())),
            ]));
        }
    }
    println!("{}", kt.render());
    if update {
        let path = std::path::Path::new("BENCH_engine.json");
        butterfly_bfs::harness::protocol::update_measured_kernel(
            path,
            Json::Arr(measured_rows),
        )
        .expect("BENCH_engine.json exists (run bench-protocol first)");
        println!("recorded kernel wallclock rows into {}", path.display());
    }
    println!(
        "note: the committed width trajectory for the fixed protocol configs \
         lives in BENCH_engine.json (butterfly-bfs bench-protocol --check)."
    );
}
