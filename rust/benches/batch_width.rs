//! Batch-width ablation: one wide batch (W ∈ {1, 2, 4, 8} lane words)
//! against the same roots executed as 64-root single-word chunks, in 1D
//! (butterfly f4) and 2D (fold/expand) — the experiment behind the
//! const-generic wide lane masks.
//!
//! Reported per (mode, width): the lane words and sparse entry bytes of
//! the wire format, sync rounds and exchange bytes for the wide batch vs
//! its chunks, and the simulated DGX-2 time per root. Distances are
//! asserted bit-identical between the wide batch and its chunks before
//! any number is printed — the chunked run *is* the correctness oracle.
//!
//! The structural claim on display: sync rounds per level are
//! width-invariant (one exchange serves the whole batch), so rounds per
//! root fall ~linearly with width, while the cohort-factored negotiated
//! encoding keeps total bytes at or below the chunked cost.
//!
//! Run: `cargo bench --bench batch_width`
//! (`BBFS_SCALE_DELTA=n` rescales the graph; `BBFS_BENCH_PROFILE=full`
//! uses the larger default.)

use butterfly_bfs::bfs::msbfs::sample_batch_roots;
use butterfly_bfs::coordinator::{BatchWidth, EngineConfig, PartitionMode, TraversalPlan};
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::table::{count, f2, ms, Table};

fn main() {
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(match std::env::var("BBFS_BENCH_PROFILE").as_deref() {
            Ok("full") => -5,
            _ => -7,
        });
    let spec = table1_suite()
        .into_iter()
        .find(|s| s.name == "kron-like")
        .unwrap();
    let g = spec.generate_scaled(scale_delta);
    println!(
        "== batch_width on {} (|V|={}, |E|={}) ==",
        spec.name,
        count(g.num_vertices() as u64),
        count(g.num_edges()),
    );
    let mut t = Table::new(&[
        "mode",
        "width",
        "W",
        "entry B",
        "rounds",
        "rounds chunked",
        "bytes",
        "bytes chunked",
        "sim ms/root",
        "chunked ms/root",
        "bytes vs chunked",
    ]);
    for mode in ["1d", "2d"] {
        for width in [64usize, 128, 256, 512] {
            let roots = sample_batch_roots(&g, width, 7);
            let base = match mode {
                "1d" => EngineConfig::dgx2(16, 4),
                _ => EngineConfig {
                    partition: PartitionMode::TwoD { rows: 4, cols: 4 },
                    ..EngineConfig::dgx2(16, 1)
                },
            };
            let cfg = EngineConfig {
                batch_width: BatchWidth::for_lanes(width)
                    .expect("bench widths are within the lane limit"),
                ..base.clone()
            };
            let plan = TraversalPlan::build(&g, cfg).expect("valid plan");
            let mut session = plan.session();
            let wide = session.run_batch(&roots).expect("roots in range");
            session.assert_batch_agreement().expect("node agreement");

            // Chunked baseline through one pooled single-word session —
            // also the oracle: every lane must match bit for bit.
            let mut chunked = TraversalPlan::build(&g, base)
                .expect("valid plan")
                .session();
            let (mut c_rounds, mut c_bytes, mut c_sim) = (0u64, 0u64, 0f64);
            for (ci, chunk) in roots.chunks(64).enumerate() {
                let cb = chunked.run_batch(chunk).expect("roots in range");
                for (lane, _) in chunk.iter().enumerate() {
                    assert_eq!(
                        cb.dist(lane),
                        wide.dist(ci * 64 + lane),
                        "{mode} width {width} chunk {ci} lane {lane}"
                    );
                }
                c_rounds += cb.metrics().sync_rounds;
                c_bytes += cb.metrics().bytes();
                c_sim += cb.metrics().sim_seconds();
            }
            let m = wide.metrics();
            t.row(vec![
                mode.to_string(),
                width.to_string(),
                m.lane_words.to_string(),
                m.entry_bytes().to_string(),
                m.sync_rounds.to_string(),
                c_rounds.to_string(),
                count(m.bytes()),
                count(c_bytes),
                ms(m.sim_seconds() / width as f64),
                ms(c_sim / width as f64),
                f2(m.bytes() as f64 / c_bytes.max(1) as f64),
            ]);
            assert!(
                m.sync_rounds <= c_rounds,
                "{mode} width {width}: wide rounds exceed chunked"
            );
        }
    }
    println!("{}", t.render());
    println!(
        "note: the committed width trajectory for the fixed protocol configs \
         lives in BENCH_engine.json (butterfly-bfs bench-protocol --check)."
    );
}
