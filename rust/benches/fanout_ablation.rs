//! Regenerates the **§5 Fanout Difference** analysis plus two ablations
//! the paper calls out:
//!
//! 1. Fanout sweep f ∈ {1, 2, 4, 8, 16} at 16 nodes — rounds vs messages
//!    vs simulated sync time (the §3 trade-off made concrete).
//! 2. The 8 → 9 node fanout-1 regression (Fig 1(f) bottleneck).
//! 3. Ablation: LRB on/off (load-balance effect on the slowest node).
//! 4. Ablation: degree-sort relabeling (the paper's future-work item).
//!
//! Run: `cargo bench --bench fanout_ablation`

use butterfly_bfs::comm::{Butterfly, CommPattern};
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::roots::{run_protocol, RootProtocol};
use butterfly_bfs::harness::table::{f2, ms, Table};
use butterfly_bfs::net::model::NetModel;
use butterfly_bfs::net::sim::simulate_uniform;
use butterfly_bfs::partition::relabel::{apply_relabeling, degree_sort_relabeling};

fn main() {
    let proto = RootProtocol::from_env();
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let spec = table1_suite().into_iter().find(|s| s.name == "kron-like").unwrap();
    let g = spec.generate_scaled(scale_delta);
    println!(
        "== Fanout ablations on {} (|V|={}, |E|={}) ==\n",
        spec.name,
        g.num_vertices(),
        g.num_edges()
    );

    // 1. Fanout sweep at 16 nodes.
    println!("-- fanout sweep, 16 nodes (paper §3 trade-off) --");
    let mut t = Table::new(&["fanout", "rounds", "messages", "sync ms (1MB msgs)", "bfs sim ms"]);
    let net = NetModel::dgx2();
    for f in [1u32, 2, 4, 8, 16] {
        let s = Butterfly::new(f).schedule(16);
        let sync = simulate_uniform(&s, &net, 1 << 20);
        let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(16, f))
            .expect("valid plan")
            .session();
        let (bfs_time, _) = run_protocol(&g, &proto, |r| {
            session.run_metrics_only(r).expect("root in range").sim_seconds()
        });
        t.row(vec![
            f.to_string(),
            s.depth().to_string(),
            s.total_messages().to_string(),
            ms(sync.total()),
            ms(bfs_time),
        ]);
    }
    println!("{}", t.render());

    // 2. The 8 -> 9 node regression.
    println!("-- 8 -> 9 node regression (Fig 1(f) bottleneck) --");
    let mut t = Table::new(&["nodes", "f1 sim ms", "f4 sim ms"]);
    for nodes in [8usize, 9] {
        let mut row = vec![nodes.to_string()];
        for f in [1u32, 4] {
            let mut session = TraversalPlan::build(&g, EngineConfig::dgx2(nodes, f))
                .expect("valid plan")
                .session();
            let (time, _) = run_protocol(&g, &proto, |r| {
                session.run_metrics_only(r).expect("root in range").sim_seconds()
            });
            row.push(ms(time));
        }
        t.row(row);
    }
    println!("{}", t.render());

    // 3. LRB ablation: effect on the slowest node's edge count.
    println!("-- LRB on/off (max node edges per level, load balance) --");
    let mut t = Table::new(&["lrb", "sim ms", "max/mean node edges"]);
    for lrb in [true, false] {
        let cfg = EngineConfig { use_lrb: lrb, ..EngineConfig::dgx2(16, 4) };
        let mut session = TraversalPlan::build(&g, cfg).expect("valid plan").session();
        let m = session.run_metrics_only(0).expect("root in range");
        let (time, _) = run_protocol(&g, &proto, |r| {
            session.run_metrics_only(r).expect("root in range").sim_seconds()
        });
        let imbalance: f64 = {
            let tot: u64 = m.levels.iter().map(|l| l.edges_examined).sum();
            let max: u64 = m.levels.iter().map(|l| l.max_node_edges).sum();
            max as f64 * 16.0 / tot.max(1) as f64
        };
        t.row(vec![lrb.to_string(), ms(time), f2(imbalance)]);
    }
    println!("{}", t.render());

    // 3b. Direction ablation (paper contribution 3 / future work: the
    // butterfly sync composes with bottom-up and direction-optimizing).
    println!("-- traversal direction (contribution 3) --");
    let mut t = Table::new(&["direction", "sim ms", "edges examined"]);
    use butterfly_bfs::coordinator::DirectionMode;
    for (name, dir) in [
        ("topdown", DirectionMode::TopDown),
        ("diropt", DirectionMode::diropt()),
    ] {
        let cfg = EngineConfig { direction: dir, ..EngineConfig::dgx2(16, 4) };
        let mut session = TraversalPlan::build(&g, cfg).expect("valid plan").session();
        let m = session.run_metrics_only(0).expect("root in range");
        let (time, _) = run_protocol(&g, &proto, |r| {
            session.run_metrics_only(r).expect("root in range").sim_seconds()
        });
        t.row(vec![name.into(), ms(time), m.edges_examined().to_string()]);
    }
    println!("{}", t.render());

    // 4. Relabeling ablation (paper future work).
    println!("-- degree-sort relabeling (paper future-work ablation) --");
    let relabeled = apply_relabeling(&g, &degree_sort_relabeling(&g));
    let mut t = Table::new(&["graph", "partition imbalance", "sim ms"]);
    for (name, graph) in [("original", &g), ("degree-sorted", &relabeled)] {
        let plan =
            TraversalPlan::build(graph, EngineConfig::dgx2(16, 4)).expect("valid plan");
        let imb = plan.partition().imbalance(graph);
        let mut session = plan.session();
        let (time, _) = run_protocol(graph, &proto, |r| {
            session.run_metrics_only(r).expect("root in range").sim_seconds()
        });
        t.row(vec![name.into(), f2(imb), ms(time)]);
    }
    println!("{}", t.render());
}
