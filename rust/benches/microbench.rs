//! Hot-path microbenchmarks for the §Perf optimization pass: the pieces
//! of the level loop measured in isolation so regressions are attributable.
//!
//! * frontier expansion (native backend, LRB on/off) — wallclock edges/s;
//! * LRB binning throughput;
//! * bitmap ops (union, iterate);
//! * butterfly schedule generation;
//! * end-to-end engine wallclock (the number §Perf tracks);
//! * XLA frontier step (when artifacts are built).
//!
//! Run: `cargo bench --bench microbench`

use butterfly_bfs::bfs::frontier::Bitmap;
use butterfly_bfs::bfs::lrb::bin_frontier;
use butterfly_bfs::bfs::topdown::topdown_bfs;
use butterfly_bfs::comm::{Butterfly, CommPattern};
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::harness::bench::{bench, black_box, BenchConfig};
use butterfly_bfs::harness::table::count;

fn main() {
    let cfg = BenchConfig::from_env();
    let scale: u32 = std::env::var("BBFS_MICRO_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let (g, _) = kronecker(KroneckerParams::graph500(scale, 16), 42);
    println!(
        "graph: kron scale {scale} ef 16 (|V|={}, |E|={})\n",
        count(g.num_vertices() as u64),
        count(g.num_edges())
    );

    // Full single-node top-down traversal (the Phase-1 engine).
    for lrb in [false, true] {
        let m = bench(&cfg, &format!("topdown/lrb={lrb}"), || {
            topdown_bfs(&g, 0, lrb)
        });
        let r = topdown_bfs(&g, 0, lrb);
        println!(
            "    -> {:.1} M examined-edges/s",
            r.edges_examined as f64 / m.seconds.median / 1e6
        );
    }

    // LRB binning alone.
    let frontier: Vec<u32> = (0..g.num_vertices() as u32).collect();
    bench(&cfg, "lrb/bin_full_vertex_set", || {
        bin_frontier(black_box(&frontier), |v| g.degree(v))
    });

    // Bitmap operations.
    let n = g.num_vertices();
    let a = Bitmap::from_queue(n, &frontier[..n / 3]);
    let b = Bitmap::from_queue(n, &frontier[n / 4..n / 2]);
    bench(&cfg, "bitmap/union", || {
        let mut x = a.clone();
        x.union_in(&b)
    });
    bench(&cfg, "bitmap/iterate", || a.iter().count());

    // Schedule generation (engine-construction path).
    bench(&cfg, "butterfly/schedule_cn64_f4", || {
        Butterfly::new(4).schedule(64)
    });

    // End-to-end distributed engine wallclock (one plan, one reused
    // session — the production query path).
    for (nodes, fanout) in [(16usize, 1u32), (16, 4)] {
        let plan = TraversalPlan::build(&g, EngineConfig::dgx2(nodes, fanout))
            .expect("valid plan");
        let mut session = plan.session();
        let m = bench(&cfg, &format!("engine/n{nodes}_f{fanout}"), || {
            session.run_metrics_only(0).expect("root in range")
        });
        let metrics = session.run_metrics_only(0).expect("root in range");
        println!(
            "    -> wall {:.1} M edges/s, sim {:.2} GTEPS (|E|/t), comm {:.1}%",
            metrics.edges_examined() as f64 / m.seconds.median / 1e6,
            metrics.sim_gteps(),
            metrics.sim_comm_fraction() * 100.0
        );
    }

    // XLA frontier step (only when the xla feature is on and artifacts
    // exist).
    #[cfg(feature = "xla")]
    {
        use butterfly_bfs::runtime::{find_artifact, ArtifactKey, FrontierStep};
        if let Some(path) = find_artifact(ArtifactKey { num_vertices: 1024 }) {
            let step = FrontierStep::load(&path, 1024).expect("artifact compiles");
            let (small, _) = kronecker(KroneckerParams::graph500(10, 8), 7);
            let slab = small.row_slice(0, small.num_vertices() as u32);
            let adj = step.adjacency_literal(&slab).unwrap();
            let mut frontier = vec![0f32; 1024];
            frontier[0] = 1.0;
            let visited = frontier.clone();
            bench(&cfg, "xla/frontier_step_v1024", || {
                step.run(&adj, &frontier, &visited).unwrap()
            });
        } else {
            println!("xla/frontier_step_v1024: skipped (run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("xla/frontier_step_v1024: skipped (build with --features xla)");
}
