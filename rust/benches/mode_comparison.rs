//! Mode comparison bench: 1D + butterfly (fanouts 1 and 4) vs the 2D
//! fold/expand checkerboard, head-to-head at p ∈ {16, 64} simulated nodes
//! — the experiment the paper argues by formula (§2–3: 2D cuts messages
//! from P to √P per peer set; butterfly cuts them further to ~log P
//! rounds of f sends).
//!
//! Reported per (graph, p, mode): measured messages and bytes, the
//! fold/expand split (2D), rounds per level, simulated DGX-2 time, and
//! the analytical message model next to the measurement — the `model`
//! column must read `match` for every 2D row
//! (`Partition2D::message_volume`) and every 1D row (schedule count ×
//! levels).
//!
//! Run: `cargo bench --bench mode_comparison`
//! (`BBFS_SCALE_DELTA=n` rescales the graphs; `BBFS_BENCH_PROFILE=full`
//! uses the larger defaults.)

use butterfly_bfs::comm::analysis::ModeVolume;
use butterfly_bfs::coordinator::{EngineConfig, PartitionMode, TraversalPlan};
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::table::{count, f2, ms, Table};
use butterfly_bfs::partition::Partition2D;

fn main() {
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(match std::env::var("BBFS_BENCH_PROFILE").as_deref() {
            Ok("full") => -4,
            _ => -6,
        });
    let root = 0u32;

    for name in ["kron-like", "webbase-like"] {
        let spec = table1_suite().into_iter().find(|s| s.name == name).unwrap();
        let g = spec.generate_scaled(scale_delta);
        println!(
            "== mode_comparison on {} (|V|={}, |E|={}), root {root} ==",
            spec.name,
            count(g.num_vertices() as u64),
            count(g.num_edges()),
        );
        let mut t = Table::new(&[
            "p",
            "mode",
            "levels",
            "rounds/level",
            "messages",
            "model",
            "bytes",
            "fold/expand bytes",
            "sim ms",
        ]);
        for p in [16usize, 64] {
            let (rows, cols) = Partition2D::near_square_grid(p as u32);
            let modes: Vec<(String, EngineConfig)> = vec![
                ("1d butterfly-f1".into(), EngineConfig::dgx2(p, 1)),
                ("1d butterfly-f4".into(), EngineConfig::dgx2(p, 4)),
                (
                    format!("2d-{rows}x{cols} fold-expand"),
                    EngineConfig::dgx2_2d(rows, cols),
                ),
            ];
            for (label, cfg) in modes {
                let plan = TraversalPlan::build(&g, cfg).expect("valid plan");
                let mut session = plan.session();
                let m = session.run_metrics_only(root).expect("root in range");
                session.assert_agreement().expect("node agreement");
                let levels = m.depth() as u64;
                let modeled = match plan.config().partition {
                    PartitionMode::OneD => {
                        plan.schedule().total_messages() * levels
                    }
                    PartitionMode::TwoD { .. } => plan
                        .partition()
                        .as_two_d()
                        .unwrap()
                        .message_volume(levels),
                };
                let volume = ModeVolume {
                    mode: label.clone(),
                    levels,
                    modeled_messages: modeled,
                    measured_messages: m.messages(),
                    measured_bytes: m.bytes(),
                };
                let split = if m.fold_messages() + m.expand_messages() > 0 {
                    format!(
                        "{} / {}",
                        count(m.fold_bytes()),
                        count(m.expand_bytes())
                    )
                } else {
                    "-".into()
                };
                t.row(vec![
                    p.to_string(),
                    label,
                    levels.to_string(),
                    f2(plan.schedule().depth() as f64),
                    count(m.messages()),
                    if volume.model_matches() {
                        format!("{} match", count(modeled))
                    } else {
                        format!("{} MISMATCH", count(modeled))
                    },
                    count(m.bytes()),
                    split,
                    ms(m.sim_seconds()),
                ]);
            }
        }
        println!("{}", t.render());
    }
    println!(
        "note: 2D messages follow P·(√P−1)·2 per level (fold + expand); the\n\
         butterfly stays at ~CN·f·log_f(CN) — fewer messages at every p here,\n\
         which is the paper's core claim against 2D decompositions."
    );
}
