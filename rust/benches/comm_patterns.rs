//! Regenerates the **§3 communication-complexity analysis** and the
//! **§5 "Other Multi-GPU BFS Algorithms"** comparison:
//!
//! 1. messages / rounds / buffer-bound vs node count, butterfly vs
//!    all-to-all (the paper's closed-form claims, measured);
//! 2. end-to-end BFS: ButterFly vs the Gunrock/Groute-shaped baseline
//!    (all-to-all + dynamic buffer allocation) on the kron_g500-logn21
//!    analog — the paper reports Gunrock *slowing down* with more GPUs
//!    and ButterFly ≈50× faster at 16.
//!
//! Run: `cargo bench --bench comm_patterns`

use butterfly_bfs::comm::analysis::{comm_costs, paper_message_formula};
use butterfly_bfs::comm::{Butterfly, CommPattern, ConcurrentAllToAll, IterativeAllToAll};
use butterfly_bfs::coordinator::{EngineConfig, PatternKind, TraversalPlan};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::harness::roots::{run_protocol, RootProtocol};
use butterfly_bfs::harness::table::{count, f2, ms, Table};
use butterfly_bfs::net::model::NetModel;

fn main() {
    let proto = RootProtocol::from_env();
    // §3 complexity table: payload = 1 MB bitmap equivalent.
    println!("== §3 message/round/buffer accounting (1 MB payloads) ==\n");
    let payload = 1u64 << 20;
    let mut t = Table::new(&[
        "CN",
        "pattern",
        "rounds",
        "messages",
        "paper formula",
        "buffer bound MB",
        "max fanout",
    ]);
    for cn in [8u32, 9, 16, 32, 64] {
        let pats: Vec<(String, Box<dyn CommPattern>)> = vec![
            ("butterfly-f1".into(), Box::new(Butterfly::new(1))),
            ("butterfly-f4".into(), Box::new(Butterfly::new(4))),
            ("alltoall-conc".into(), Box::new(ConcurrentAllToAll)),
            ("alltoall-iter".into(), Box::new(IterativeAllToAll)),
        ];
        for (name, p) in pats {
            let s = p.schedule(cn);
            let c = comm_costs(&s, payload);
            let formula = if name.starts_with("butterfly") {
                let f = if name.ends_with("f1") { 1 } else { 4 };
                format!("{}", paper_message_formula(cn, f) as u64)
            } else {
                format!("{}", (cn as u64) * (cn as u64 - 1))
            };
            t.row(vec![
                cn.to_string(),
                name,
                c.rounds.to_string(),
                c.messages.to_string(),
                formula,
                f2(c.buffer_bytes as f64 / (1 << 20) as f64),
                c.max_fanout.to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    // §5 other-multi-GPU comparison on the kron_g500-logn21 analog.
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let scale = ((16 + scale_delta).max(8)) as u32;
    let (g, _) = kronecker(KroneckerParams::graph500(scale, 44), 0xB0B0_1021);
    println!(
        "== §5 vs Gunrock/Groute-shaped baseline (kron_g500-logn21 analog: |V|={}, |E|={}) ==\n",
        count(g.num_vertices() as u64),
        count(g.num_edges())
    );
    let mut t = Table::new(&[
        "nodes",
        "butterfly-f4 ms",
        "naive (a2a+dynalloc) ms",
        "butterfly speedup",
    ]);
    let mut prev_naive = 0.0;
    let mut naive_increases = true;
    for nodes in [2usize, 4, 8, 16] {
        let mut bf = TraversalPlan::build(&g, EngineConfig::dgx2(nodes, 4))
            .expect("valid plan")
            .session();
        let (t_bf, _) = run_protocol(&g, &proto, |r| {
            bf.run_metrics_only(r).expect("root in range").sim_seconds()
        });
        let naive_cfg = EngineConfig {
            pattern: PatternKind::AllToAllConcurrent,
            net: NetModel::dynamic_alloc_baseline(),
            ..EngineConfig::dgx2(nodes, 1)
        };
        let mut naive = TraversalPlan::build(&g, naive_cfg).expect("valid plan").session();
        let (t_naive, _) = run_protocol(&g, &proto, |r| {
            naive.run_metrics_only(r).expect("root in range").sim_seconds()
        });
        if nodes > 2 && t_naive < prev_naive {
            naive_increases = false;
        }
        prev_naive = t_naive;
        t.row(vec![
            nodes.to_string(),
            ms(t_bf),
            ms(t_naive),
            f2(t_naive / t_bf),
        ]);
    }
    println!("{}", t.render());
    println!(
        "naive baseline time increases with node count: {} (paper: Gunrock's \"execution time \
         increased with each additional GPU\")",
        naive_increases
    );
}
