//! Open-loop load generator for the `serve` mode: a fixed arrival rate
//! (not closed-loop — requests are sent on schedule whether or not
//! earlier responses have come back, so queueing delay is *measured*,
//! not hidden), driven over a real localhost socket.
//!
//! The same arrival schedule runs twice: against a no-coalescing server
//! (window 0, max batch 1) and against the coalescing configuration —
//! the wallclock counterpart of the deterministic `serve_throughput.sim`
//! section in `BENCH_engine.json`. Per mode the report carries the
//! server-side latency percentiles (nearest-rank, integer µs), the
//! client-observed qps over the active window, and the coalesced
//! batch-width distribution.
//!
//! Run: `cargo bench --bench serve_throughput`
//! `--update` records the measured report into `BENCH_engine.json`'s
//! `serve_throughput.measured` subtree (excluded from the freshness
//! compare, sanity-checked by `bench-protocol --check`).

use butterfly_bfs::bfs::msbfs::sample_batch_roots;
use butterfly_bfs::coordinator::{BatchWidth, DirectionMode, EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::protocol::update_measured_serve;
use butterfly_bfs::serve::{ServeConfig, Server};
use butterfly_bfs::util::cli::{Args, CliError};
use butterfly_bfs::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let spec = Args::new(
        "serve_throughput",
        "open-loop load generator for the serve mode (baseline vs coalesced)",
    )
    .opt("requests", "400", "requests per mode")
    .opt("gap-us", "300", "fixed inter-arrival gap in microseconds")
    .opt("window-us", "2000", "coalescing window of the coalesced mode")
    .opt("max-batch", "64", "max coalesced batch width (1..=512)")
    .opt("queue-depth", "256", "admission-queue bound")
    .opt("workers", "2", "server worker threads")
    .opt("scale-delta", "-10", "kron-like scale adjustment (protocol default)")
    .opt("out", "BENCH_engine.json", "artifact path for --update")
    .flag("update", "record the measured report into the committed artifact");
    // `cargo bench` passes a literal `--bench` to harness=false targets.
    let argv = std::env::args().skip(1).filter(|s| s != "--bench");
    let a = match spec.clone().parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            println!("{}", spec.help_text());
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let requests: usize = a.get_usize("requests").unwrap();
    let gap_us: u64 = a.get_u64("gap-us").unwrap();
    let window_us: u64 = a.get_u64("window-us").unwrap();
    let max_batch: usize = a.get_usize("max-batch").unwrap();
    let queue_depth: usize = a.get_usize("queue-depth").unwrap();
    let workers: usize = a.get_usize("workers").unwrap();
    let scale_delta: i32 = a.get_parse("scale-delta").unwrap();
    if BatchWidth::for_lanes(max_batch).is_none() {
        eprintln!("error: --max-batch must be in 1..=512 (got {max_batch})");
        std::process::exit(2);
    }

    let g = table1_suite()
        .into_iter()
        .find(|s| s.name == "kron-like")
        .unwrap()
        .generate_scaled(scale_delta);
    let cfg = EngineConfig {
        direction: DirectionMode::TopDown,
        batch_width: BatchWidth::for_lanes(max_batch).unwrap(),
        ..EngineConfig::dgx2(16, 4)
    };
    let plan = Arc::new(TraversalPlan::build(&g, cfg).expect("valid engine configuration"));
    println!(
        "== serve_throughput on kron-like (|V|={}, |E|={}) — {requests} requests, \
         {gap_us} us gap ==",
        g.num_vertices(),
        g.num_edges()
    );

    let baseline = run_mode(&plan, &g, 0, 1, queue_depth, workers, requests, gap_us);
    let coalesced =
        run_mode(&plan, &g, window_us, max_batch, queue_depth, workers, requests, gap_us);
    summarize("baseline ", &baseline);
    summarize("coalesced", &coalesced);

    let measured = Json::obj(vec![
        ("requests", Json::u(requests as u64)),
        ("gap_us", Json::u(gap_us)),
        ("baseline", baseline),
        ("coalesced", coalesced),
    ]);
    println!("{}", Json::obj(vec![("serve_throughput_measured", measured.clone())]).render());
    if a.get_flag("update") {
        let path = a.get("out");
        if let Err(e) = update_measured_serve(Path::new(&path), measured) {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
        println!("recorded measured serve report into {path}");
    }
}

/// One mode: boot a server, fire the open-loop schedule, collect every
/// response, shut down cleanly, and merge server + client views.
#[allow(clippy::too_many_arguments)]
fn run_mode(
    plan: &Arc<TraversalPlan>,
    g: &butterfly_bfs::graph::csr::Csr,
    window_us: u64,
    max_batch: usize,
    queue_depth: usize,
    workers: usize,
    requests: usize,
    gap_us: u64,
) -> Json {
    let server = Server::bind(
        Arc::clone(plan),
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers,
            coalesce_window_us: window_us,
            max_batch,
            queue_depth,
            default_timeout_us: None,
        },
    )
    .expect("bind ephemeral port");
    let addr = server.local_addr().expect("bound address");
    let server_thread = std::thread::spawn(move || server.run().expect("server run"));

    let roots = sample_batch_roots(g, 512.min(requests), 11);
    let stream = TcpStream::connect(addr).expect("connect to server");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("set read timeout");
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);

    let t0 = Instant::now();
    let writer_thread = std::thread::spawn(move || {
        for i in 0..requests {
            // Open loop: hold the schedule regardless of response
            // progress (sleep to the absolute deadline, not by the gap).
            let due = Duration::from_micros(i as u64 * gap_us);
            let now = t0.elapsed();
            if due > now {
                std::thread::sleep(due - now);
            }
            let req = Json::obj(vec![
                ("op", Json::s("query")),
                ("id", Json::u(i as u64)),
                ("root", Json::u(roots[i % roots.len()] as u64)),
            ]);
            writer.write_all(req.render().as_bytes()).expect("send request");
            writer.write_all(b"\n").expect("send request");
        }
        writer
    });

    // Every query gets exactly one response (ok / overloaded / timeout /
    // error); read until all are accounted for.
    let mut line = String::new();
    let mut ok = 0u64;
    let mut last_ok_us = 0u64;
    for _ in 0..requests {
        line.clear();
        let n = reader.read_line(&mut line).expect("response before read timeout");
        assert!(n > 0, "server closed the connection mid-run");
        let resp = Json::parse(line.trim()).expect("valid response JSON");
        if resp.get("status").and_then(|s| s.as_str()) == Some("ok") {
            ok += 1;
            last_ok_us = t0.elapsed().as_micros() as u64;
        }
    }
    let mut writer = writer_thread.join().expect("writer thread");

    // Clean shutdown: the server drains and its run() returns the final
    // metrics report.
    writer.write_all(b"{\"op\":\"shutdown\"}\n").expect("send shutdown");
    line.clear();
    reader.read_line(&mut line).expect("shutdown ack");
    let ack = Json::parse(line.trim()).expect("valid shutdown ack");
    assert_eq!(
        ack.get("shutting_down").map(|b| b == &Json::Bool(true)),
        Some(true),
        "expected a shutdown acknowledgement"
    );
    let mut report = server_thread.join().expect("server thread");

    // The server's elapsed time includes boot/shutdown slack; qps over
    // the client's active window is the honest figure.
    let span_us = last_ok_us.max(1);
    let qps = ok as f64 * 1e6 / span_us as f64;
    if let Json::Obj(map) = &mut report {
        map.insert("qps".to_string(), Json::n(qps));
        map.insert("offered".to_string(), Json::u(requests as u64));
        map.insert("window_us".to_string(), Json::u(window_us));
        map.insert("max_batch".to_string(), Json::u(max_batch as u64));
        map.insert("span_us".to_string(), Json::u(span_us));
    }
    report
}

fn summarize(name: &str, r: &Json) {
    let f = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "{name}  completed {:>5}  rejected {:>4}  p50 {:>7} us  p99 {:>7} us  \
         qps {:>8.0}  mean width {:>5.1}",
        f("completed"),
        f("rejected"),
        f("p50_us"),
        f("p99_us"),
        f("qps"),
        f("mean_batch_width"),
    );
}
