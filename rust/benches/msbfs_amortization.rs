//! MS-BFS amortization bench: 64 sequential `run()` calls vs one
//! `run_batch` over the same 64 roots, at several fanouts — the batched
//! traversal pays schedule setup, message latency, and dedup traffic once
//! per level for the whole batch instead of once per root.
//!
//! Reported per fanout: total synchronization bytes, schedule rounds,
//! messages, simulated DGX-2 time, and wallclock, plus the
//! sequential/batch amortization ratios. Rounds and messages drop by
//! roughly the batch width (~55× here) — the headline win, since message
//! latency and schedule setup dominate small frontiers. Bytes drop
//! strictly but modestly (~1.1–1.3×) for random root sets — the
//! mask-grouped delta encoding (`bfs::msbfs::mask_delta_bytes`) exploits
//! lanes traveling together, which separate runs cannot — and sharply
//! (>10×) for overlapping or duplicate root batches.
//!
//! Run: `cargo bench --bench msbfs_amortization`
//! (`BBFS_SCALE_DELTA=n` rescales the graphs; `BBFS_BENCH_PROFILE=full`
//! uses the larger defaults.)

use butterfly_bfs::bfs::msbfs::sample_batch_roots;
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::csr::VertexId;
use butterfly_bfs::graph::gen::table1_suite;
use butterfly_bfs::harness::table::{count, f2, ms, Table};

fn main() {
    let scale_delta: i32 = std::env::var("BBFS_SCALE_DELTA")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(match std::env::var("BBFS_BENCH_PROFILE").as_deref() {
            Ok("full") => -4,
            _ => -6,
        });
    let nodes = 16usize;
    let batch = 64usize;

    for name in ["kron-like", "webbase-like"] {
        let spec = table1_suite().into_iter().find(|s| s.name == name).unwrap();
        let g = spec.generate_scaled(scale_delta);
        let roots: Vec<VertexId> = sample_batch_roots(&g, batch, 0xBA7C4);
        println!(
            "== msbfs_amortization on {} (|V|={}, |E|={}), {} roots, {} nodes ==",
            spec.name,
            count(g.num_vertices() as u64),
            count(g.num_edges()),
            batch,
            nodes
        );
        let mut t = Table::new(&[
            "fanout",
            "mode",
            "sync rounds",
            "messages",
            "bytes",
            "sim ms",
            "wall ms",
        ]);
        for fanout in [1u32, 2, 4, 8] {
            let plan = TraversalPlan::build(&g, EngineConfig::dgx2(nodes, fanout))
                .expect("valid plan");
            let mut session = plan.session();

            // 64 sequential single-root traversals.
            let t0 = std::time::Instant::now();
            let seq = session.sequential_baseline(&roots).expect("roots in range");
            let seq_wall = t0.elapsed().as_secs_f64();

            // One batched traversal over the same roots.
            let t0 = std::time::Instant::now();
            let batch_result = session.run_batch(&roots).expect("valid batch");
            let batch_wall = t0.elapsed().as_secs_f64();
            session.assert_batch_agreement().expect("batch agreement");
            let bm = batch_result.metrics();

            t.row(vec![
                fanout.to_string(),
                format!("{batch}x run()"),
                seq.sync_rounds.to_string(),
                count(seq.messages),
                count(seq.bytes),
                ms(seq.sim_seconds),
                ms(seq_wall),
            ]);
            t.row(vec![
                String::new(),
                "run_batch".into(),
                bm.sync_rounds.to_string(),
                count(bm.messages()),
                count(bm.bytes()),
                ms(bm.sim_seconds()),
                ms(batch_wall),
            ]);
            t.row(vec![
                String::new(),
                "ratio".into(),
                f2(seq.sync_rounds as f64 / bm.sync_rounds.max(1) as f64),
                f2(seq.messages as f64 / bm.messages().max(1) as f64),
                f2(seq.bytes as f64 / bm.bytes().max(1) as f64),
                f2(seq.sim_seconds / bm.sim_seconds().max(1e-12)),
                f2(seq_wall / batch_wall.max(1e-12)),
            ]);
        }
        println!("{}", t.render());
    }
}
