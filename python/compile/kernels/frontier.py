"""L1 Pallas kernel: tiled frontier expansion for the MXU.

GPU->TPU adaptation (DESIGN.md section 6): the paper's CUDA hot loop is an
irregular per-warp frontier expansion balanced by LRB. The MXU-regular
form of the same work is a tiled 0/1 vector-matrix product over the
boolean semiring: frontier (1, V) times adjacency (V, V), saturated, then
masked by the visited set. BlockSpec expresses the HBM->VMEM schedule the
CUDA version expressed with threadblocks:

  * grid = (V/T, V/T) over (reduction tiles k, output tiles j);
  * adjacency streams through VMEM one (T, T) tile at a time;
  * the (1, T) output tile stays resident across the k-loop (accumulator);
  * saturation + visited-masking happen in the epilogue of the last k
    step, so the output bitmap never round-trips to HBM unsaturated.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU lowering would only change the `pallas_call`
backend, not the kernel. VMEM/MXU estimates for the real-TPU variant are
recorded in EXPERIMENTS.md section Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic-array tile edge. 128x128 f32 tiles: one adjacency tile is
# 64 KiB of VMEM; with the (1, T) frontier, visited, and output tiles the
# working set stays ~200 KiB -- far under the ~16 MiB VMEM budget, leaving
# room for double-buffering the adjacency stream.
TILE = 128


def _expand_kernel(f_ref, a_ref, v_ref, o_ref, *, nk):
    """One grid step: accumulate f-tile @ a-tile into the output tile.

    Grid is (nk, nj): k = reduction index over the V dimension,
    j = output-column tile. The output tile is revisited across k
    (accumulator-in-VMEM pattern); the epilogue at k == nk-1 saturates to
    0/1 and applies the visited mask.
    """
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(f_ref[...], a_ref[...])

    @pl.when(k == nk - 1)
    def _epilogue():
        saturated = jnp.minimum(o_ref[...], 1.0)
        o_ref[...] = saturated * (1.0 - v_ref[...])


@functools.partial(jax.jit, static_argnames=("tile",))
def frontier_expand(adj, frontier, visited, *, tile=TILE):
    """One BFS level step via the Pallas kernel.

    Args:
      adj: ``f32[V, V]`` 0/1 adjacency slab (V divisible by ``tile``).
      frontier: ``f32[V]`` 0/1 frontier indicator.
      visited: ``f32[V]`` 0/1 visited indicator.
      tile: VMEM tile edge (default 128, the MXU shape).

    Returns:
      ``f32[V]`` 0/1 newly-discovered indicator.
    """
    v = adj.shape[0]
    assert adj.shape == (v, v), f"adjacency must be square, got {adj.shape}"
    assert frontier.shape == (v,) and visited.shape == (v,)
    assert v % tile == 0, f"V={v} must be a multiple of tile={tile}"
    nk = v // tile
    nj = v // tile

    f2 = frontier.reshape(1, v)
    vis2 = visited.reshape(1, v)

    out = pl.pallas_call(
        functools.partial(_expand_kernel, nk=nk),
        grid=(nk, nj),
        in_specs=[
            # frontier: row vector, reduction tile k.
            pl.BlockSpec((1, tile), lambda k, j: (0, k)),
            # adjacency: (k, j) tile of the matrix.
            pl.BlockSpec((tile, tile), lambda k, j: (k, j)),
            # visited: output-column tile j (used in the epilogue).
            pl.BlockSpec((1, tile), lambda k, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda k, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, v), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(f2, adj, vis2)
    return out.reshape(v)


def vmem_bytes(tile=TILE):
    """Estimated VMEM working set of one grid step (for DESIGN/EXPERIMENTS):
    one adjacency tile + frontier, visited, and output row tiles, double-
    buffered adjacency stream."""
    adj_tile = tile * tile * 4
    row_tiles = 3 * tile * 4
    return 2 * adj_tile + row_tiles  # x2: double buffering
