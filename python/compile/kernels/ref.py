"""Pure-jnp oracle for the frontier-expansion kernel.

One BFS level in the Buluc-Madduri BLAS formulation over the boolean
semiring, carried in f32 0/1 values (MXU-native):

    reached = saturate(frontier @ adj)          # OR over in-neighbors
    new     = reached * (1 - visited)           # first-discovery mask

``adj[i, j] = 1`` iff arc ``i -> j`` exists *and* row ``i`` is owned by the
executing compute node (rows of foreign nodes are zero -- the 1D partition
slab densified; see rust/src/runtime/executable.rs).

This module is the correctness contract: the Pallas kernel
(``kernels/frontier.py``) and the AOT artifact must match it bit-for-bit
on 0/1 inputs.
"""

import jax.numpy as jnp


def frontier_step_ref(adj, frontier, visited):
    """Reference frontier expansion.

    Args:
      adj: ``f32[V, V]`` 0/1 adjacency slab (row-owned arcs only).
      frontier: ``f32[V]`` 0/1 active-frontier indicator.
      visited: ``f32[V]`` 0/1 already-discovered indicator.

    Returns:
      ``f32[V]`` 0/1 vector of newly discovered vertices.
    """
    reached = jnp.minimum(frontier @ adj, 1.0)
    return reached * (1.0 - visited)


def bfs_reference(adj, root, max_levels):
    """Full multi-level BFS distances via the reference step (test oracle).

    Returns ``i32[V]`` distances with ``-1`` for unreachable vertices.
    """
    v = adj.shape[0]
    dist = jnp.full((v,), -1, dtype=jnp.int32).at[root].set(0)
    visited = jnp.zeros((v,), dtype=jnp.float32).at[root].set(1.0)
    frontier = jnp.zeros((v,), dtype=jnp.float32).at[root].set(1.0)
    for level in range(1, max_levels + 1):
        new = frontier_step_ref(adj, frontier, visited)
        dist = jnp.where(new > 0.5, level, dist)
        visited = jnp.minimum(visited + new, 1.0)
        frontier = new
    return dist
