"""L1: Pallas kernels for the paper's compute hot-spot (frontier expansion)
plus the pure-jnp oracle they are verified against."""

from .frontier import TILE, frontier_expand, vmem_bytes  # noqa: F401
from .ref import bfs_reference, frontier_step_ref  # noqa: F401
