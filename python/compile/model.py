"""L2: the per-compute-node BFS level step as a JAX computation.

This is the model the Rust coordinator executes via PJRT: given a node's
densified adjacency slab, the current frontier bitmap, and the visited
bitmap, produce the newly-discovered bitmap. The inner product is the L1
Pallas kernel; everything lowers into one HLO module per padded size
(``aot.py``).

The step is deliberately side-effect-free and fixed-shape: the L3
coordinator owns all state (queues, distance arrays, the butterfly
exchange), calling this step once per node per level -- mirroring how the
paper's CUDA kernel is launched by the OpenMP host threads.
"""

import jax
import jax.numpy as jnp

from .kernels.frontier import frontier_expand
from .kernels.ref import frontier_step_ref


def frontier_step(adj, frontier, visited):
    """One BFS level on one compute node (Pallas-kernel path).

    Args:
      adj: ``f32[V, V]`` 0/1 row-owned adjacency slab.
      frontier: ``f32[V]`` 0/1 frontier indicator (owned vertices only;
        foreign rows of ``adj`` are zero so foreign frontier bits are
        harmless).
      visited: ``f32[V]`` 0/1 this-node-knows indicator.

    Returns:
      A 1-tuple ``(new,)`` with ``f32[V]`` 0/1 discoveries, matching the
      ``return_tuple=True`` convention the Rust loader unwraps.
    """
    return (frontier_expand(adj, frontier, visited),)


def frontier_step_jnp(adj, frontier, visited):
    """Same computation on the pure-jnp path (fallback / A-B testing)."""
    return (frontier_step_ref(adj, frontier, visited),)


def example_args(num_vertices):
    """ShapeDtypeStructs for lowering at a given padded size."""
    v = num_vertices
    return (
        jax.ShapeDtypeStruct((v, v), jnp.float32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
    )
