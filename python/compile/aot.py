"""AOT lowering: JAX model -> HLO text artifacts for the Rust runtime.

HLO *text* (never ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/load_hlo/ and the repo DESIGN.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Emits frontier_step_v{256,1024,2048}.hlo.txt plus a manifest.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import example_args, frontier_step

# Padded sizes to emit; must stay in sync with
# rust/src/runtime/artifacts.rs::ARTIFACT_SIZES.
SIZES = (256, 1024, 2048)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_frontier_step(num_vertices: int) -> str:
    lowered = jax.jit(frontier_step).lower(*example_args(num_vertices))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes", type=int, nargs="*", default=list(SIZES),
        help="padded vertex counts to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for v in args.sizes:
        text = lower_frontier_step(v)
        name = f"frontier_step_v{v}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {"num_vertices": v, "chars": len(text)}
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
