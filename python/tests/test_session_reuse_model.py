"""Pure-python spec of the QuerySession pooled-reuse semantics (PR 3).

Line-for-line port of `rust/src/bfs/msbfs.rs::MsBfsNodeState`
(``discover`` / ``swap_level`` / ``reset``) and the distributed batch
level loop's CopyFrontier exchange, used to verify the one behavioral
change this PR makes to the traversal path: `run_batch` now *reuses* the
per-node lane state across batches via ``reset`` instead of
reallocating it.

Checked over random graph/engine configs:

* a reused (reset) state produces per-lane distances identical to a
  fresh state and to the serial BFS oracle — across batches of
  different widths, including duplicate roots;
* the per-level delta statistics that feed the negotiated payload
  pricing (`delta_distinct`, distinct mask values, active lanes) are
  identical for reused and fresh states. This is where ``reset``'s
  level-stamp zeroing matters: ``swap_level`` deliberately leaves
  ``delta_stamp`` behind (stamps are ``level + 1`` and levels only grow
  within a batch), but a *new* batch restarts levels at 0, so stale
  stamps from a previous batch would suppress `delta_distinct`
  increments and mis-price payloads. The `no_reset` regression below
  demonstrates exactly that failure, proving the test can see the bug
  the Rust ``reset`` prevents.

No jax/hypothesis needed — runs everywhere CI runs.
"""

import random

INF = 0xFFFFFFFF
ENTRY_BYTES = 12


def serial_bfs(n, adj, root):
    dist = [INF] * n
    dist[root] = 0
    frontier = [root]
    level = 0
    while frontier:
        nxt = []
        for v in frontier:
            for u in adj[v]:
                if dist[u] == INF:
                    dist[u] = level + 1
                    nxt.append(u)
        frontier = nxt
        level += 1
    return dist


def mask_delta_bytes(entries, distinct_vertices, distinct_masks, active_lanes, n):
    if entries == 0:
        return 0
    presence = -(-n // 64) * 8
    sparse = entries * ENTRY_BYTES
    grouped = distinct_masks * 12 + entries * 4
    dense = presence + distinct_vertices * 8
    lane_bitmaps = (1 + active_lanes) * presence
    return min(sparse, grouped, dense, lane_bitmaps)


class MsBfsNodeState:
    """Port of `MsBfsNodeState` with its pooled `reset`."""

    def __init__(self, n, num_roots):
        self.n = n
        self.seen = [0] * n
        self.dist = [INF] * (n * num_roots)
        self.visit = [0] * n
        self.next_mask = [0] * n
        self.q_local = []
        self.q_local_next = []
        self.delta = []  # list of (vertex, mask)
        self.edges_this_level = 0
        self.delta_distinct = 0
        self.mask_values = set()
        self.active_lanes = 0
        self.delta_stamp = [0] * n

    def reset(self, num_roots, *, skip_stamps=False):
        """`MsBfsNodeState::reset`. `skip_stamps` models the bug the
        Rust implementation avoids (leaving `delta_stamp` dirty)."""
        self.seen = [0] * self.n
        self.dist = [INF] * (self.n * num_roots)
        self.visit = [0] * self.n
        self.next_mask = [0] * self.n
        self.q_local = []
        self.q_local_next = []
        self.delta = []
        self.edges_this_level = 0
        self.delta_distinct = 0
        self.mask_values = set()
        self.active_lanes = 0
        if not skip_stamps:
            self.delta_stamp = [0] * self.n

    def discover(self, v, mask, level, owned):
        d = mask & ~self.seen[v]
        if d == 0:
            return 0
        self.seen[v] |= d
        m = d
        while m:
            lane = (m & -m).bit_length() - 1
            m &= m - 1
            self.dist[lane * self.n + v] = level + 1
        self.delta.append((v, d))
        if self.delta_stamp[v] != level + 1:
            self.delta_stamp[v] = level + 1
            self.delta_distinct += 1
        self.active_lanes |= d
        self.mask_values.add(d)
        if owned:
            if self.next_mask[v] == 0:
                self.q_local_next.append(v)
            self.next_mask[v] |= d
        return d

    def swap_level(self):
        self.q_local = self.q_local_next
        self.q_local_next = []
        for v in self.q_local:
            self.visit[v] = self.next_mask[v]
            self.next_mask[v] = 0
        self.delta = []
        self.delta_distinct = 0
        self.mask_values = set()
        self.active_lanes = 0
        # delta_stamp deliberately NOT cleared (mirrors swap_level).
        self.edges_this_level = 0


def partition_cuts(n, parts):
    return [n * p // parts for p in range(parts + 1)]


def run_batch(n, adj, states, cuts, roots):
    """The distributed batched level loop over (possibly reused) states.

    The exchange is modeled as a single allgather round with CopyFrontier
    semantics (every node replays every other node's frozen delta
    prefix), which the butterfly/fold-expand schedules are proven
    equivalent to by `verify_full_coverage` on the Rust side. Returns
    (per-lane distances of node 0, per-level pricing statistics).
    """
    parts = len(states)
    b = len(roots)

    def owns(k, v):
        return cuts[k] <= v < cuts[k + 1]

    # Prologue ("All CN set their d").
    for k, st in enumerate(states):
        for lane, r in enumerate(roots):
            bit = 1 << lane
            st.seen[r] |= bit
            st.dist[lane * n + r] = 0
            if owns(k, r):
                if st.visit[r] == 0:
                    st.q_local.append(r)
                st.visit[r] |= bit

    pricing = []
    level = 0
    while sum(len(st.q_local) for st in states) > 0:
        # Phase 1: masked expansion of the owned frontier.
        for k, st in enumerate(states):
            q = st.q_local
            st.q_local = []
            for v in q:
                mv = st.visit[v]
                st.visit[v] = 0
                st.edges_this_level += len(adj[v])
                for u in adj[v]:
                    st.discover(u, mv, level, owns(k, u))
            del q  # Rust restores the drained list only to keep its allocation

        # Phase 2: one allgather round, frozen prefixes. The trace
        # records exactly what `delta_payload_bytes` snapshots on the
        # Rust side: the frozen prefix length, the (clamped) coalescing
        # statistics, and the priced bytes they yield.
        snap = []
        for st in states:
            entries = len(st.delta)
            distinct = min(st.delta_distinct, entries)
            masks = min(len(st.mask_values), entries)
            lanes = bin(st.active_lanes).count("1")
            snap.append(
                (entries, distinct, masks, lanes,
                 mask_delta_bytes(entries, distinct, masks, lanes, n))
            )
        pricing.append(tuple(snap))
        for src in range(parts):
            take = snap[src][0]
            prefix = states[src].delta[:take]
            for dst in range(parts):
                if dst == src:
                    continue
                for v, m in prefix:
                    states[dst].discover(v, m, level, owns(dst, v))

        for st in states:
            st.swap_level()
        level += 1

    return [states[0].dist[lane * n + v] for lane in range(b) for v in range(n)], pricing


def random_graph(rng, n, ef):
    adj = [set() for _ in range(n)]
    for _ in range(n * ef):
        u = rng.randrange(n)
        v = rng.randrange(n)
        if u != v:
            adj[u].add(v)
            adj[v].add(u)
    return [sorted(s) for s in adj]


def test_reused_states_match_fresh_and_serial():
    rng = random.Random(0xB3)
    for _ in range(60):
        n = rng.randrange(8, 120)
        adj = random_graph(rng, n, rng.randrange(1, 5))
        parts = rng.randrange(1, min(6, n) + 1)
        cuts = partition_cuts(n, parts)
        pooled = [MsBfsNodeState(n, 1) for _ in range(parts)]
        first = True
        # Three back-to-back batches of different widths on the SAME
        # pooled states, each compared against fresh states + the oracle.
        for _ in range(3):
            b = rng.randrange(1, 17)
            roots = [rng.randrange(n) for _ in range(b)]
            if b >= 2:
                roots[1] = roots[0]  # duplicate lanes stay legal
            if not first:
                for st in pooled:
                    st.reset(b)
            else:
                pooled = [MsBfsNodeState(n, b) for _ in range(parts)]
                first = False
            dist_reused, pricing_reused = run_batch(n, adj, pooled, cuts, roots)
            fresh = [MsBfsNodeState(n, b) for _ in range(parts)]
            dist_fresh, pricing_fresh = run_batch(n, adj, fresh, cuts, roots)
            assert dist_reused == dist_fresh
            assert pricing_reused == pricing_fresh
            for lane, r in enumerate(roots):
                want = serial_bfs(n, adj, r)
                got = dist_reused[lane * n : (lane + 1) * n]
                assert got == want, f"n={n} parts={parts} lane={lane}"


def test_stale_stamps_would_misprice_payloads():
    # The regression `reset`'s stamp-zeroing prevents: reuse WITHOUT
    # clearing delta_stamp must (on some config) disagree with the fresh
    # pricing trace — stale `level+1` stamps from the previous batch
    # suppress `delta_distinct`, corrupting the statistics that bound
    # the dense serialization form.
    rng = random.Random(7)
    saw_difference = False
    for _ in range(40):
        n = rng.randrange(8, 80)
        adj = random_graph(rng, n, 3)
        parts = rng.randrange(1, 5)
        cuts = partition_cuts(n, parts)
        roots_a = [rng.randrange(n) for _ in range(8)]
        roots_b = [rng.randrange(n) for _ in range(8)]
        dirty = [MsBfsNodeState(n, 8) for _ in range(parts)]
        run_batch(n, adj, dirty, cuts, roots_a)
        for st in dirty:
            st.reset(8, skip_stamps=True)
        _, pricing_dirty = run_batch(n, adj, dirty, cuts, roots_b)
        fresh = [MsBfsNodeState(n, 8) for _ in range(parts)]
        _, pricing_fresh = run_batch(n, adj, fresh, cuts, roots_b)
        if pricing_dirty != pricing_fresh:
            saw_difference = True
            break
    assert saw_difference, "stale stamps never observable — regression test is vacuous"
