"""Executable spec of the hierarchical grid-of-islands exchange.

The Rust engine's hierarchical mode (``PartitionMode::Hierarchical`` +
``comm::GridOfIslands``) composes a butterfly inside each island with a
butterfly across island representatives and a final rep -> island
broadcast, priced under a two-class link model
(``net::model::TopologyModel`` + ``net::sim::simulate_topology``). This
suite checks the Python port of that composition against first
principles: the schedule must be a complete dissemination pattern, the
class split must tile the totals, a uniform topology must reproduce flat
pricing bit-for-bit, distances must stay bit-identical to the serial BFS
oracle in every direction mode, and under a 10:1 intra:inter bandwidth
ratio the hierarchical layout must beat flat 1D at p = 64 — the
tentpole claim the CI-checked BENCH_engine.json `hierarchical` section
records.
"""

import random

import bench_protocol_port as bp


def rand_graph(rng, n, ef):
    return bp.uniform_random(n, ef, rng.randrange(1 << 60))


# ---------------------------------------------------------------------------
# Schedule shape + dissemination
# ---------------------------------------------------------------------------


def test_hierarchical_schedule_shape_and_class_split():
    for islands in range(1, 9):
        for per_island in range(1, 9):
            for fanout in [1, 2, 4]:
                nodes = islands * per_island
                rounds = bp.hierarchical_schedule(islands, per_island, fanout)
                intra_depth = len(bp.butterfly_schedule(per_island, fanout))
                inter_depth = len(bp.butterfly_schedule(islands, fanout))
                bcast = 1 if islands > 1 and per_island > 1 else 0
                assert len(rounds) == intra_depth + inter_depth + bcast
                for rnd in rounds[:intra_depth]:
                    # Intra phase never crosses an island boundary.
                    assert all(s // per_island == d // per_island
                               for (s, d) in rnd)
                for rnd in rounds[intra_depth:]:
                    # Inter + broadcast phases touch representatives only
                    # as sources.
                    assert all(s % per_island == 0 for (s, _) in rnd)
                for rnd in rounds:
                    assert all(0 <= s < nodes and 0 <= d < nodes and s != d
                               for (s, d) in rnd)
                    assert rnd == sorted(rnd), "deterministic transfer order"
                intra, inter = bp.class_volume(rounds, per_island)
                assert intra + inter == sum(len(r) for r in rounds)
                if islands > 1:
                    assert inter > 0
                if per_island > 1:
                    assert intra > 0


def test_degenerate_grids_reduce_to_flat_butterfly():
    # 1 x P: one island — identical to the flat butterfly over P ranks.
    for p, fanout in [(2, 1), (5, 2), (8, 4)]:
        assert (bp.hierarchical_schedule(1, p, fanout)
                == bp.butterfly_schedule(p, fanout))
    # P x 1: every rank is its own representative — the flat butterfly
    # again (representative mapping is the identity).
    for p, fanout in [(2, 1), (5, 2), (8, 4)]:
        assert (bp.hierarchical_schedule(p, 1, fanout)
                == bp.butterfly_schedule(p, fanout))


def test_schedule_disseminates_all_to_all():
    """Round-synchronous token closure: with CopyFrontier semantics
    (transfers see round-start state) every rank must end up knowing
    every rank's token — the property that makes one exchange per BFS
    level sufficient."""
    for islands in range(1, 9):
        for per_island in range(1, 9):
            for fanout in [1, 2, 4]:
                nodes = islands * per_island
                know = [{r} for r in range(nodes)]
                for rnd in bp.hierarchical_schedule(islands, per_island, fanout):
                    snap = [set(k) for k in know]
                    for (s, d) in rnd:
                        know[d] |= snap[s]
                assert all(len(k) == nodes for k in know), (
                    f"{islands}x{per_island} fanout {fanout}"
                )


# ---------------------------------------------------------------------------
# Two-class pricing
# ---------------------------------------------------------------------------


def test_uniform_topology_reproduces_flat_pricing():
    rng = random.Random(0x01)
    for _ in range(10):
        cn = rng.randrange(2, 10)
        rounds = bp.butterfly_schedule(cn, rng.randrange(1, 5))
        payloads = [[rng.randrange(0, 1 << 20) for _ in rnd] for rnd in rounds]
        want_times, want_bytes, want_msgs = bp.simulate_schedule(
            rounds, payloads, cn)
        topo = dict(name="uniform", per_island=1 << 30,
                    intra=dict(bp.DGX2), inter=dict(bp.DGX2))
        times, tot = bp.simulate_topology(rounds, payloads, cn, topo)
        assert times == want_times, "must be bit-identical, not just close"
        assert (tot["bytes"], tot["messages"]) == (want_bytes, want_msgs)
        assert tot["inter_messages"] == 0 and tot["inter_bytes"] == 0
        assert tot["intra_messages"] == want_msgs


def test_inter_class_contends_per_island_uplink():
    # Two islands of 2; both members of island 0 message both members of
    # island 1 in one round. The inter class is re-addressed to island
    # endpoints, so island 0's shared uplink serializes all 4 sends:
    # setup latency * ceil(4/2) + max(4B / (2 * link_bw), 2 slots * B / link_bw).
    B = 1 << 20
    rounds = [[(0, 2), (0, 3), (1, 2), (1, 3)]]
    payloads = [[B] * 4]
    topo = bp.dgx2_cluster_topo(2)
    up = bp.ISLAND_UPLINK
    times, tot = bp.simulate_topology(rounds, payloads, 4, topo)
    assert tot["inter_messages"] == 4 and tot["intra_messages"] == 0
    expect = up["latency"] * 2 + 2 * B / up["link_bw"]
    assert abs(times[0] - expect) / expect < 1e-12, (times[0], expect)


def test_cluster_pricing_prefers_hierarchical_at_p64():
    """The static half of the tentpole claim: at p = 64 under the 10:1
    dgx2-cluster model, the grid-of-islands schedule both moves fewer
    inter-island messages and prices strictly faster than the flat
    butterfly, at any uniform payload."""
    flat = bp.butterfly_schedule(64, 4)
    hier = bp.hierarchical_schedule(8, 8, 4)
    topo = bp.dgx2_cluster_topo(8)
    _, flat_inter = bp.class_volume(flat, 8)
    _, hier_inter = bp.class_volume(hier, 8)
    assert hier_inter < flat_inter
    for payload in [1 << 10, 1 << 20]:
        tf, _ = bp.simulate_topology(
            flat, [[payload] * len(r) for r in flat], 64, topo)
        th, _ = bp.simulate_topology(
            hier, [[payload] * len(r) for r in hier], 64, topo)
        assert sum(th) < sum(tf), (payload, sum(th), sum(tf))


# ---------------------------------------------------------------------------
# End-to-end equivalence (the engine contract)
# ---------------------------------------------------------------------------


def test_hier_mode_matches_serial_oracle_every_direction():
    rng = random.Random(0x15A)
    for _ in range(18):
        n = rng.randrange(20, 140)
        g = rand_graph(rng, n, rng.randrange(1, 5))
        b = rng.randrange(1, 17)
        roots = [rng.randrange(n) for _ in range(b)]
        want = [bp.serial_bfs(g, r) for r in roots]
        islands = rng.randrange(1, 5)
        per_island = rng.randrange(1, 5)
        fanout = rng.randrange(1, 5)
        topo = bp.dgx2_cluster_topo(per_island) if rng.random() < 0.5 else None
        for d in ["topdown", "bottomup", "diropt"]:
            m = bp.run_batch(g, islands * per_island, fanout, roots, d,
                             mode="hier", grid=(islands, per_island),
                             topo=topo)
            for lane in range(b):
                assert m["dist"][lane] == want[lane], (
                    f"n={n} grid={islands}x{per_island} f={fanout} {d} "
                    f"lane {lane}"
                )


def test_hier_levels_carry_class_split_that_tiles_totals():
    rng = random.Random(0xC1A)
    g = rand_graph(rng, 150, 3)
    roots = [rng.randrange(150) for _ in range(8)]
    for mode, grid in [("1d", None), ("2d", (3, 2)), ("hier", (2, 3))]:
        m = bp.run_batch(g, 6, 2, roots, "topdown", mode=mode, grid=grid,
                         topo=bp.dgx2_cluster_topo(3))
        for l in m["levels"]:
            assert l["intra_messages"] + l["inter_messages"] == l["messages"]
            assert l["intra_bytes"] + l["inter_bytes"] == l["bytes"]
