"""L1 kernel correctness: Pallas frontier_expand vs the pure-jnp oracle
and a plain-numpy BFS-step oracle, across shapes, densities, and seeds
(hypothesis), plus analytic edge cases."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import TILE, frontier_expand, frontier_step_ref, vmem_bytes

SIZES = [128, 256, 384]


def numpy_oracle(adj, frontier, visited):
    """Independent numpy formulation of one BFS step."""
    reached = (adj[frontier.astype(bool)].sum(axis=0) > 0).astype(np.float32)
    return reached * (1.0 - visited)


def random_case(v, density, frontier_p, visited_p, seed):
    rng = np.random.default_rng(seed)
    adj = (rng.random((v, v)) < density).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    frontier = (rng.random(v) < frontier_p).astype(np.float32)
    # visited must contain the frontier (BFS invariant).
    visited = np.maximum(frontier, (rng.random(v) < visited_p).astype(np.float32))
    return adj, frontier, visited


@pytest.mark.parametrize("v", SIZES)
def test_kernel_matches_ref_basic(v):
    adj, f, vis = random_case(v, 0.03, 0.1, 0.2, seed=v)
    got = np.array(frontier_expand(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    want = np.array(frontier_step_ref(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("v", SIZES)
def test_kernel_matches_numpy_oracle(v):
    adj, f, vis = random_case(v, 0.05, 0.15, 0.1, seed=100 + v)
    got = np.array(frontier_expand(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    want = numpy_oracle(adj, f, vis)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    v=st.sampled_from(SIZES),
    density=st.floats(0.0, 0.2),
    frontier_p=st.floats(0.0, 1.0),
    visited_p=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(v, density, frontier_p, visited_p, seed):
    adj, f, vis = random_case(v, density, frontier_p, visited_p, seed)
    got = np.array(frontier_expand(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    want = np.array(frontier_step_ref(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    np.testing.assert_array_equal(got, want)
    # BFS-step invariants: output is 0/1 and disjoint from visited.
    assert set(np.unique(got)).issubset({0.0, 1.0})
    assert np.all(got * vis == 0.0)


def test_empty_frontier_discovers_nothing():
    v = 256
    adj, _, _ = random_case(v, 0.05, 0.0, 0.0, seed=1)
    f = np.zeros(v, dtype=np.float32)
    vis = np.zeros(v, dtype=np.float32)
    got = np.array(frontier_expand(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    assert got.sum() == 0.0


def test_all_visited_discovers_nothing():
    v = 128
    adj, f, _ = random_case(v, 0.1, 0.3, 0.0, seed=2)
    vis = np.ones(v, dtype=np.float32)
    got = np.array(frontier_expand(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    assert got.sum() == 0.0


def test_path_graph_single_step():
    """Analytic case: a directed path 0->1->...->V-1."""
    v = 256
    adj = np.zeros((v, v), dtype=np.float32)
    adj[np.arange(v - 1), np.arange(1, v)] = 1.0
    f = np.zeros(v, dtype=np.float32)
    f[7] = 1.0
    vis = f.copy()
    got = np.array(frontier_expand(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    want = np.zeros(v, dtype=np.float32)
    want[8] = 1.0
    np.testing.assert_array_equal(got, want)


def test_hub_saturation():
    """A hub with every in-edge: counts > 1 must saturate to exactly 1.0."""
    v = 128
    adj = np.zeros((v, v), dtype=np.float32)
    adj[:, 0] = 1.0  # everyone points at vertex 0
    adj[0, 0] = 0.0
    f = np.ones(v, dtype=np.float32)
    f[0] = 0.0
    vis = f.copy()
    got = np.array(frontier_expand(jnp.array(adj), jnp.array(f), jnp.array(vis)))
    assert got[0] == 1.0  # exactly 1.0, not 127.0
    assert got.sum() == 1.0


def test_non_multiple_of_tile_rejected():
    v = 100
    adj = jnp.zeros((v, v), dtype=jnp.float32)
    f = jnp.zeros(v, dtype=jnp.float32)
    with pytest.raises(AssertionError):
        frontier_expand(adj, f, f)


def test_vmem_budget():
    """The BlockSpec working set must fit VMEM with double buffering."""
    assert vmem_bytes(TILE) < 16 * 1024 * 1024
    # and stays modest: ~130 KiB for the default tile.
    assert vmem_bytes(TILE) < 256 * 1024
