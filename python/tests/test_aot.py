"""AOT pipeline tests: lowering produces loadable HLO text whose entry
computation has the expected parameter/result shapes, and the emitted
text re-executes correctly through jax's own HLO-module path."""

import os
import subprocess
import sys

import pytest

from compile.aot import lower_frontier_step, SIZES


def test_lowering_produces_hlo_text():
    text = lower_frontier_step(256)
    assert "HloModule" in text
    assert "f32[256,256]" in text  # adjacency parameter
    assert "f32[256]" in text  # frontier/visited parameters
    # return_tuple convention: the root is a tuple.
    assert "(f32[256]" in text or "tuple" in text


def test_sizes_match_rust_side():
    # rust/src/runtime/artifacts.rs::ARTIFACT_SIZES must list the same
    # sizes; parse the source to keep the two in lockstep.
    here = os.path.dirname(__file__)
    rs = os.path.join(here, "..", "..", "rust", "src", "runtime", "artifacts.rs")
    with open(rs) as f:
        src = f.read()
    line = next(l for l in src.splitlines() if "ARTIFACT_SIZES" in l and "=" in l)
    rust_sizes = [int(x) for x in line.rsplit("&[", 1)[1].split("]")[0].split(",")]
    assert rust_sizes == list(SIZES)


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--sizes", "256"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert (out / "frontier_step_v256.hlo.txt").exists()
    assert (out / "manifest.json").exists()


@pytest.mark.parametrize("v", [256])
def test_ids_fit_32bit(v):
    """The interchange constraint: HLO text must parse back into ids the
    0.5.1 extension accepts; text ids are small by construction, but keep
    a tripwire on module size."""
    text = lower_frontier_step(v)
    assert len(text.splitlines()) < 5000
