"""L2 model tests: the jitted frontier_step (Pallas path) vs the jnp path,
multi-level composition against a python BFS, and lowering shape checks."""

import collections

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import bfs_reference
from compile.model import example_args, frontier_step, frontier_step_jnp


def python_bfs(adj, root):
    """Plain python BFS oracle over a dense adjacency matrix."""
    v = adj.shape[0]
    dist = [-1] * v
    dist[root] = 0
    q = collections.deque([root])
    while q:
        u = q.popleft()
        for w in np.nonzero(adj[u])[0]:
            if dist[w] == -1:
                dist[w] = dist[u] + 1
                q.append(int(w))
    return np.array(dist, dtype=np.int32)


def random_sym_adj(v, density, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((v, v)) < density).astype(np.float32)
    a = np.maximum(a, a.T)
    np.fill_diagonal(a, 0.0)
    return a


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pallas_and_jnp_paths_agree(seed):
    v = 256
    adj = random_sym_adj(v, 0.02, seed)
    rng = np.random.default_rng(100 + seed)
    f = (rng.random(v) < 0.1).astype(np.float32)
    vis = np.maximum(f, (rng.random(v) < 0.3).astype(np.float32))
    (a,) = frontier_step(jnp.array(adj), jnp.array(f), jnp.array(vis))
    (b,) = frontier_step_jnp(jnp.array(adj), jnp.array(f), jnp.array(vis))
    np.testing.assert_array_equal(np.array(a), np.array(b))


def test_multi_level_bfs_matches_python():
    v = 128
    adj = random_sym_adj(v, 0.03, seed=7)
    want = python_bfs(adj, root=5)
    got = np.array(bfs_reference(jnp.array(adj), 5, max_levels=v))
    np.testing.assert_array_equal(got, want)


def test_multi_level_via_frontier_step():
    """Drive the Pallas step level by level like the Rust engine does."""
    v = 128
    adj = random_sym_adj(v, 0.04, seed=9)
    want = python_bfs(adj, root=0)
    dist = np.full(v, -1, dtype=np.int32)
    dist[0] = 0
    frontier = np.zeros(v, dtype=np.float32)
    frontier[0] = 1.0
    visited = frontier.copy()
    level = 0
    while frontier.sum() > 0:
        (new,) = frontier_step(jnp.array(adj), jnp.array(frontier), jnp.array(visited))
        new = np.array(new)
        level += 1
        dist[new > 0.5] = level
        visited = np.minimum(visited + new, 1.0)
        frontier = new
    np.testing.assert_array_equal(dist, want)


def test_example_args_shapes():
    a, f, vis = example_args(1024)
    assert a.shape == (1024, 1024)
    assert f.shape == (1024,)
    assert vis.shape == (1024,)
    assert str(a.dtype) == "float32"
