"""Pure-python spec of the SIMD-shaped mask kernels (this PR).

Drives the line-for-line engine port in ``bench_protocol_port`` — the
same code that generates the committed ``BENCH_engine.json`` — through
the kernel-ablation semantics the Rust engine must honor:

* every kernel variant (auto/scalar/chunked, LRB on/off) is
  bit-identical on distances, wire bytes, probed edges, and sync rounds
  — the counters are observers, never participants;
* the deterministic work-counter model: the scalar sweep reads W words
  per owned vertex (and never skips), the chunked sweep pays one
  summary word per 64-vertex chunk and elides settled vertices, the
  dense merge walks only occupied snapshot slots under the chunked
  kernel, and LRB degree-binning splits the probe into uniform
  dispatches without moving a single word counter;
* the committed ``kernel_ablation`` section's shape and acceptance
  invariants, entry for entry, against a freshly computed model run.

No jax/hypothesis needed — runs everywhere CI runs.
"""

import json
import os

import bench_protocol_port as bp

BENCH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))), "BENCH_engine.json")

VARIANTS = [("auto", True), ("scalar", True),
            ("chunked", True), ("chunked", False)]


def run(g, roots, direction, kernel, use_lrb, **kw):
    return bp.run_batch(g, 4, 2, roots, direction, kernel=kernel,
                        use_lrb=use_lrb,
                        width_words=bp.words_for_lanes(len(roots)), **kw)


def test_kernel_variants_bit_identical_everywhere():
    g = bp.uniform_random(220, 4, 0xFEED)
    roots = [(i * 13 + 5) % g.n for i in range(90)]
    want = [bp.serial_bfs(g, r) for r in roots]
    for kw in [dict(), dict(mode="2d", grid=(2, 2)),
               dict(mode="hier", grid=(2, 2),
                    topo=bp.dgx2_cluster_topo(2))]:
        for d in ["topdown", "bottomup", "diropt"]:
            sig = None
            for kernel, use_lrb in VARIANTS:
                m = run(g, roots, d, kernel, use_lrb, **kw)
                assert m["dist"] == want, (kw, d, kernel, use_lrb)
                got = (m["sync_rounds"], m["reached_pairs"],
                       [(l["edges"], l["bytes"], l["messages"])
                        for l in m["levels"]])
                if sig is None:
                    sig = got
                else:
                    assert got == sig, (kw, d, kernel, use_lrb)


def test_scalar_never_skips_chunked_always_does():
    g = bp.uniform_random(300, 5, 0xABBA)
    roots = [(i * 3 + 1) % g.n for i in range(100)]
    s = bp.kernel_work_totals(run(g, roots, "bottomup", "scalar", True))
    c = bp.kernel_work_totals(run(g, roots, "bottomup", "chunked", True))
    assert s["words_skipped"] == 0
    assert c["words_skipped"] > 0
    assert c["words_touched"] < s["words_touched"]
    # The sparse tail is where the settled-skip pays hardest.
    assert c["tail_words"] < s["tail_words"]


def test_scalar_sweep_counts_w_words_per_owned_vertex():
    # Single level, single node, top-down off the table: a pure
    # bottom-up run's first level touches exactly W words per vertex
    # (sweep) plus the phase-2 merge traffic, which for an all-sparse
    # exchange is W per replayed entry.
    g = bp.uniform_random(64, 2, 7)
    roots = [0]
    m = run(g, roots, "bottomup", "scalar", True)
    l0 = m["levels"][0]
    # 4 nodes sweep their ranges: total = W * n; sparse replays add
    # W * take per transfer.
    sweep = 1 * g.n
    assert l0["words_touched"] >= sweep
    assert l0["words_skipped"] == 0


def test_lrb_moves_dispatches_never_words():
    g = bp.uniform_random(400, 6, 0xD15C)
    roots = [(i * 17 + 2) % g.n for i in range(128)]
    lrb = bp.kernel_work_totals(run(g, roots, "bottomup", "chunked", True))
    flat = bp.kernel_work_totals(run(g, roots, "bottomup", "chunked", False))
    assert lrb["words_touched"] == flat["words_touched"]
    assert lrb["words_skipped"] == flat["words_skipped"]
    assert lrb["dispatches"] >= flat["dispatches"]
    assert lrb["dispatch_max_work"] <= flat["dispatch_max_work"]


def test_lrb_shrinks_max_dispatch_on_skewed_degrees():
    # A star graph is the degenerate skew: one hub candidate dominates
    # the flat probe dispatch; binning isolates it.
    n = 257
    g = bp.build_undirected(n, [(0, v) for v in range(1, n)])
    roots = [(i * 5 + 1) % n for i in range(70)]
    want = [bp.serial_bfs(g, r) for r in roots]
    # One node, like the Rust backend unit test: the hub and its leaves
    # land in the same sweep, so the flat probe dispatch sums both
    # degree classes while LRB isolates them.
    w = bp.words_for_lanes(len(roots))
    lrb = bp.run_batch(g, 1, 2, roots, "bottomup", kernel="chunked",
                       use_lrb=True, width_words=w)
    flat = bp.run_batch(g, 1, 2, roots, "bottomup", kernel="chunked",
                        use_lrb=False, width_words=w)
    assert lrb["dist"] == want and flat["dist"] == want
    lt = bp.kernel_work_totals(lrb)
    ft = bp.kernel_work_totals(flat)
    assert lt["dispatch_max_work"] < ft["dispatch_max_work"], (lt, ft)


def test_bin_of_degree_matches_lrb_rs():
    assert bp.bin_of_degree(0) == 0
    assert bp.bin_of_degree(1) == 0
    assert bp.bin_of_degree(2) == 1
    assert bp.bin_of_degree(3) == 2
    assert bp.bin_of_degree(4) == 2
    assert bp.bin_of_degree(5) == 3
    assert bp.bin_of_degree(1 << 20) == 20
    assert bp.bin_of_degree((1 << 20) + 1) == 21


def test_chunk_range_mask_matches_backend_rs():
    assert bp.chunk_range_mask(0, 0, 64) == bp.MASK64
    assert bp.chunk_range_mask(0, 0, 1) == 1
    assert bp.chunk_range_mask(0, 63, 64) == 1 << 63
    assert bp.chunk_range_mask(1, 0, 64) == 0
    assert bp.chunk_range_mask(1, 70, 130) == (((1 << 58) - 1) << 6)
    assert bp.chunk_range_mask(2, 70, 130) == (1 << 2) - 1


def test_committed_kernel_ablation_section():
    """The committed BENCH_engine.json kernel section must match a fresh
    model run entry for entry, and satisfy the acceptance gates."""
    with open(BENCH) as f:
        committed = json.load(f)
    assert committed["protocol"] == bp.PROTOCOL["name"]
    entries = committed["kernel_ablation"]
    assert len(entries) == 3 * len(bp.PROTOCOL["kernel_widths"])
    scale = max(bp.PROTOCOL["kron_scale"] + bp.PROTOCOL["scale_delta"], 4)
    g = bp.kronecker(scale, bp.PROTOCOL["kron_edge_factor"],
                     bp.PROTOCOL["kron_seed"])
    fresh = bp.kernel_ablation(g)
    assert committed["kernel_ablation"] == fresh
    for entry in entries:
        key = (entry["mode"], entry["width"])
        assert entry["distances_equal"] is True, key
        s, c, n = entry["scalar"], entry["chunked"], entry["no_lrb"]
        assert c["words_touched"] < s["words_touched"], key
        assert c["tail_words"] < s["tail_words"], key
        assert s["words_skipped"] == 0, key
        assert c["words_skipped"] > 0, key
        assert c["dispatch_max_work"] < n["dispatch_max_work"], key
        assert entry["lane_words"] == bp.words_for_lanes(entry["width"])
