"""Executable spec of the 2D fold/expand exchange (dependency-free).

The Rust engine's 2D mode (``PartitionMode::TwoD`` + ``comm::FoldExpand``)
is specified here as a ~100-line pure-Python model and checked against a
serial BFS oracle: distances must agree on *every* processor of the grid,
and the per-level message count must equal the analytical model
``P*(cols-1) + P*(rows-1)`` (``Partition2D::message_volume`` on the Rust
side). This file is the cross-layer contract: if the Rust implementation
and this spec ever disagree about what fold/expand means, one of the two
test suites goes red.
"""

import random

INF = 2**32 - 1


def serial_bfs(n, adj, root):
    dist = [INF] * n
    dist[root] = 0
    q, d = [root], 0
    while q:
        nq = []
        for v in q:
            for u in adj[v]:
                if dist[u] == INF:
                    dist[u] = d + 1
                    nq.append(u)
        q = nq
        d += 1
    return dist


def partition_1d_cuts(n, offsets, parts):
    """Edge-balanced greedy prefix cuts (mirrors partition_1d in Rust)."""
    m = offsets[n]
    cuts, v = [0], 0
    for p in range(1, parts):
        target = m * p / parts
        max_v = n - (parts - p)
        while v < max_v and offsets[v + 1] < target:
            v += 1
        v = min(max(v, cuts[-1] + 1), max_v)
        cuts.append(v)
    cuts.append(n)
    return cuts


def weight_balanced_cuts(weights, parts):
    """Greedy prefix cuts over arbitrary per-vertex weights (mirrors
    weight_balanced_cuts in Rust; the 2D column cuts use in-degrees)."""
    n = len(weights)
    total = float(sum(weights))
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)
    cuts, v = [0], 0
    for p in range(1, parts):
        target = total * p / parts
        max_v = n - (parts - p)
        while v < max_v and prefix[v + 1] < target:
            v += 1
        v = min(max(v, cuts[-1] + 1), max_v)
        cuts.append(v)
    cuts.append(n)
    return cuts


def col_cuts_for(n, adj, cols):
    """Edge-balanced (by in-degree) target-axis cuts — the column-cut
    policy of ``Partition2D::new``."""
    in_deg = [0] * n
    for u in range(n):
        for w in adj[u]:
            in_deg[w] += 1
    return weight_balanced_cuts(in_deg, cols)


def fold_expand_schedule(rows, cols):
    """Fold along processor rows, then expand along columns."""
    rounds, rank = [], lambda i, j: i * cols + j
    if cols > 1:
        rounds.append([
            (rank(i, j), rank(i, j2))
            for i in range(rows) for j in range(cols)
            for j2 in range(cols) if j2 != j
        ])
    if rows > 1:
        rounds.append([
            (rank(i, j), rank(i2, j))
            for i in range(rows) for j in range(cols)
            for i2 in range(rows) if i2 != i
        ])
    return rounds


class Proc:
    """One grid processor: full distance view + its edge block."""

    def __init__(self, n, srcs, block):
        self.srcs, self.block = srcs, block
        self.d = [INF] * n
        self.visited = [False] * n
        self.q_local, self.q_next, self.q_global = [], [], []

    def owns(self, v):
        return self.srcs[0] <= v < self.srcs[1]

    def discover(self, v, level):
        if self.visited[v]:
            return
        self.visited[v] = True
        self.d[v] = level + 1
        self.q_global.append(v)
        if self.owns(v):
            self.q_next.append(v)


def run_2d(n, adj, offsets, rows, cols, root):
    row_cuts = partition_1d_cuts(n, offsets, rows)
    col_cuts = col_cuts_for(n, adj, cols)
    sched = fold_expand_schedule(rows, cols)
    procs = []
    for i in range(rows):
        rlo, rhi = row_cuts[i], row_cuts[i + 1]
        for j in range(cols):
            clo, chi = col_cuts[j], col_cuts[j + 1]
            block = {u: [w for w in adj[u] if clo <= w < chi]
                     for u in range(rlo, rhi)}
            procs.append(Proc(n, (rlo, rhi), block))
    for p in procs:
        p.d[root] = 0
        p.visited[root] = True
        if p.owns(root):
            p.q_local.append(root)
    level = messages = levels = 0
    while any(procs[i * cols].q_local for i in range(rows)):
        levels += 1
        for p in procs:
            for v in p.q_local:
                for u in p.block[v]:
                    p.discover(u, level)
        for rnd in sched:  # CopyFrontier: transfers see round-start state
            snap = [len(p.q_global) for p in procs]
            for (src, dst) in rnd:
                messages += 1
                for k in range(snap[src]):
                    procs[dst].discover(procs[src].q_global[k], level)
        for p in procs:
            p.q_local, p.q_next, p.q_global = p.q_next, [], []
        level += 1
    return procs, messages, levels


def random_graph(rng, n, ef):
    edges = set()
    for _ in range(n * ef):
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.add((u, v))
            edges.add((v, u))
    adj = [[] for _ in range(n)]
    for (u, v) in sorted(edges):
        adj[u].append(v)
    offsets = [0]
    for v in range(n):
        offsets.append(offsets[-1] + len(adj[v]))
    return adj, offsets


def test_fold_expand_matches_serial_and_message_model():
    rng = random.Random(0x2D)
    for _ in range(60):
        n = rng.randrange(2, 120)
        adj, offsets = random_graph(rng, n, rng.randrange(1, 5))
        rows = rng.randrange(1, min(6, n) + 1)
        cols = rng.randrange(1, min(6, n) + 1)
        root = rng.randrange(n)
        want = serial_bfs(n, adj, root)
        procs, messages, levels = run_2d(n, adj, offsets, rows, cols, root)
        for k, p in enumerate(procs):
            assert p.d == want, (
                f"n={n} grid={rows}x{cols} root={root}: processor {k} disagrees"
            )
        model = levels * (rows * cols) * ((cols - 1) + (rows - 1))
        assert messages == model, f"n={n} grid={rows}x{cols}: {messages} != {model}"


def test_degenerate_grids():
    # 1x1 never communicates; 1xP folds only; Px1 expands only.
    adj = [[1], [0, 2], [1]]
    offsets = [0, 1, 3, 4]
    for (rows, cols, expected_partners) in [(1, 1, 0), (1, 3, 2), (3, 1, 2)]:
        procs, messages, levels = run_2d(3, adj, offsets, rows, cols, 0)
        want = serial_bfs(3, adj, 0)
        assert all(p.d == want for p in procs)
        assert messages == levels * rows * cols * expected_partners


def test_col_cuts_are_in_edge_balanced():
    rng = random.Random(0xC01)
    for _ in range(40):
        n = rng.randrange(2, 150)
        adj, _ = random_graph(rng, n, rng.randrange(1, 5))
        cols = rng.randrange(1, min(8, n) + 1)
        cuts = col_cuts_for(n, adj, cols)
        assert cuts[0] == 0 and cuts[-1] == n
        assert all(a < b for a, b in zip(cuts, cuts[1:]))
        in_deg = [0] * n
        for u in range(n):
            for w in adj[u]:
                in_deg[w] += 1
        per = [sum(in_deg[cuts[j]:cuts[j + 1]]) for j in range(cols)]
        assert sum(per) == sum(in_deg)
        ideal = sum(in_deg) / cols
        bound = 2 * ideal + (max(in_deg) if in_deg else 0)
        assert all(p <= bound for p in per), (n, cols, per)


# ---------------------------------------------------------------------------
# Batched (MS-BFS) direction-aware spec: up to 64 traversals as lane masks,
# each level expanded top-down (frontier scatters masks) or bottom-up (an
# unseen vertex accumulates ``acc |= visit_full[u]`` over its block
# neighbors, early-exiting once every missing lane found a parent). The
# exchange relays (vertex, mask) deltas with CopyFrontier semantics. The
# contract: distances are bit-identical per lane to serial BFS *for every
# per-level direction assignment* — this is what makes the Rust engine's
# ``run_batch`` direction equivalence suite meaningful.
# ---------------------------------------------------------------------------


class BatchProc:
    """One grid processor of the batched model (lane-mask state)."""

    def __init__(self, n, srcs, block, nroots):
        self.n, self.srcs, self.block = n, srcs, block
        self.seen = [0] * n
        self.visit = [0] * n
        self.next_mask = [0] * n
        self.visit_full = [0] * n
        self.dist = [[INF] * n for _ in range(nroots)]
        self.q_local, self.q_next, self.delta = [], [], []

    def owns(self, v):
        return self.srcs[0] <= v < self.srcs[1]

    def discover(self, v, mask, level, owned):
        d = mask & ~self.seen[v]
        if d == 0:
            return
        self.seen[v] |= d
        lane = 0
        m = d
        while m:
            if m & 1:
                self.dist[lane][v] = level + 1
            m >>= 1
            lane += 1
        self.delta.append((v, d))
        if owned:
            if self.next_mask[v] == 0:
                self.q_next.append(v)
            self.next_mask[v] |= d


def run_2d_batch(n, adj, offsets, rows, cols, roots, direction_for_level):
    """Direction-aware batched traversal over the checkerboard grid.

    ``direction_for_level(level)`` returns True for a bottom-up level —
    any assignment must produce identical distances.
    """
    row_cuts = partition_1d_cuts(n, offsets, rows)
    col_cuts = col_cuts_for(n, adj, cols)
    sched = fold_expand_schedule(rows, cols)
    full = (1 << len(roots)) - 1
    procs = []
    for i in range(rows):
        rlo, rhi = row_cuts[i], row_cuts[i + 1]
        for j in range(cols):
            clo, chi = col_cuts[j], col_cuts[j + 1]
            block = {u: [w for w in adj[u] if clo <= w < chi]
                     for u in range(rlo, rhi)}
            procs.append(BatchProc(n, (rlo, rhi), block, len(roots)))
    for p in procs:
        for lane, r in enumerate(roots):
            bit = 1 << lane
            p.seen[r] |= bit
            p.dist[lane][r] = 0
            p.visit_full[r] |= bit
            if p.owns(r):
                if p.visit[r] == 0:
                    p.q_local.append(r)
                p.visit[r] |= bit
    level = 0
    while any(procs[i * cols].q_local for i in range(rows)):
        bottom_up = direction_for_level(level)
        for p in procs:
            if bottom_up:
                found = []
                for v in range(p.srcs[0], p.srcs[1]):
                    missing = full & ~p.seen[v]
                    if missing == 0:
                        continue
                    acc = 0
                    for u in p.block[v]:
                        acc |= p.visit_full[u]
                        if acc & missing == missing:
                            break
                    d = acc & missing
                    if d:
                        found.append((v, d))
                for (v, d) in found:
                    p.discover(v, d, level, True)
            else:
                for v in p.q_local:
                    mv = p.visit[v]
                    p.visit[v] = 0
                    for u in p.block[v]:
                        p.discover(u, mv, level, p.owns(u))
        for rnd in sched:  # CopyFrontier: transfers see round-start state
            snap = [len(p.delta) for p in procs]
            for (src, dst) in rnd:
                for k in range(snap[src]):
                    v, m = procs[src].delta[k]
                    procs[dst].discover(v, m, level, procs[dst].owns(v))
        for p in procs:
            p.visit_full = [0] * n
            for (v, m) in p.delta:
                p.visit_full[v] |= m
            p.q_local, p.q_next, p.delta = p.q_next, [], []
            for v in p.q_local:
                p.visit[v] = p.next_mask[v]
                p.next_mask[v] = 0
        level += 1
    return procs


def test_batched_directions_match_serial_per_lane_on_grids():
    rng = random.Random(0xD1A)
    policies = [
        ("topdown", lambda lvl: False),
        ("bottomup", lambda lvl: True),
        ("alternating", lambda lvl: lvl % 2 == 1),
    ]
    for _ in range(25):
        n = rng.randrange(2, 100)
        adj, offsets = random_graph(rng, n, rng.randrange(1, 5))
        rows = rng.randrange(1, min(4, n) + 1)
        cols = rng.randrange(1, min(4, n) + 1)
        b = rng.randrange(1, 9)
        roots = [rng.randrange(n) for _ in range(b)]
        want = [serial_bfs(n, adj, r) for r in roots]
        for name, policy in policies:
            procs = run_2d_batch(n, adj, offsets, rows, cols, roots, policy)
            for k, p in enumerate(procs):
                for lane in range(b):
                    assert p.dist[lane] == want[lane], (
                        f"n={n} grid={rows}x{cols} {name} proc {k} lane {lane}"
                    )
