"""Executable spec of the 2D fold/expand exchange (dependency-free).

The Rust engine's 2D mode (``PartitionMode::TwoD`` + ``comm::FoldExpand``)
is specified here as a ~100-line pure-Python model and checked against a
serial BFS oracle: distances must agree on *every* processor of the grid,
and the per-level message count must equal the analytical model
``P*(cols-1) + P*(rows-1)`` (``Partition2D::message_volume`` on the Rust
side). This file is the cross-layer contract: if the Rust implementation
and this spec ever disagree about what fold/expand means, one of the two
test suites goes red.
"""

import random

INF = 2**32 - 1


def serial_bfs(n, adj, root):
    dist = [INF] * n
    dist[root] = 0
    q, d = [root], 0
    while q:
        nq = []
        for v in q:
            for u in adj[v]:
                if dist[u] == INF:
                    dist[u] = d + 1
                    nq.append(u)
        q = nq
        d += 1
    return dist


def partition_1d_cuts(n, offsets, parts):
    """Edge-balanced greedy prefix cuts (mirrors partition_1d in Rust)."""
    m = offsets[n]
    cuts, v = [0], 0
    for p in range(1, parts):
        target = m * p / parts
        max_v = n - (parts - p)
        while v < max_v and offsets[v + 1] < target:
            v += 1
        v = min(max(v, cuts[-1] + 1), max_v)
        cuts.append(v)
    cuts.append(n)
    return cuts


def fold_expand_schedule(rows, cols):
    """Fold along processor rows, then expand along columns."""
    rounds, rank = [], lambda i, j: i * cols + j
    if cols > 1:
        rounds.append([
            (rank(i, j), rank(i, j2))
            for i in range(rows) for j in range(cols)
            for j2 in range(cols) if j2 != j
        ])
    if rows > 1:
        rounds.append([
            (rank(i, j), rank(i2, j))
            for i in range(rows) for j in range(cols)
            for i2 in range(rows) if i2 != i
        ])
    return rounds


class Proc:
    """One grid processor: full distance view + its edge block."""

    def __init__(self, n, srcs, block):
        self.srcs, self.block = srcs, block
        self.d = [INF] * n
        self.visited = [False] * n
        self.q_local, self.q_next, self.q_global = [], [], []

    def owns(self, v):
        return self.srcs[0] <= v < self.srcs[1]

    def discover(self, v, level):
        if self.visited[v]:
            return
        self.visited[v] = True
        self.d[v] = level + 1
        self.q_global.append(v)
        if self.owns(v):
            self.q_next.append(v)


def run_2d(n, adj, offsets, rows, cols, root):
    row_cuts = partition_1d_cuts(n, offsets, rows)
    col_cuts = [n * j // cols for j in range(cols + 1)]
    sched = fold_expand_schedule(rows, cols)
    procs = []
    for i in range(rows):
        rlo, rhi = row_cuts[i], row_cuts[i + 1]
        for j in range(cols):
            clo, chi = col_cuts[j], col_cuts[j + 1]
            block = {u: [w for w in adj[u] if clo <= w < chi]
                     for u in range(rlo, rhi)}
            procs.append(Proc(n, (rlo, rhi), block))
    for p in procs:
        p.d[root] = 0
        p.visited[root] = True
        if p.owns(root):
            p.q_local.append(root)
    level = messages = levels = 0
    while any(procs[i * cols].q_local for i in range(rows)):
        levels += 1
        for p in procs:
            for v in p.q_local:
                for u in p.block[v]:
                    p.discover(u, level)
        for rnd in sched:  # CopyFrontier: transfers see round-start state
            snap = [len(p.q_global) for p in procs]
            for (src, dst) in rnd:
                messages += 1
                for k in range(snap[src]):
                    procs[dst].discover(procs[src].q_global[k], level)
        for p in procs:
            p.q_local, p.q_next, p.q_global = p.q_next, [], []
        level += 1
    return procs, messages, levels


def random_graph(rng, n, ef):
    edges = set()
    for _ in range(n * ef):
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.add((u, v))
            edges.add((v, u))
    adj = [[] for _ in range(n)]
    for (u, v) in sorted(edges):
        adj[u].append(v)
    offsets = [0]
    for v in range(n):
        offsets.append(offsets[-1] + len(adj[v]))
    return adj, offsets


def test_fold_expand_matches_serial_and_message_model():
    rng = random.Random(0x2D)
    for _ in range(60):
        n = rng.randrange(2, 120)
        adj, offsets = random_graph(rng, n, rng.randrange(1, 5))
        rows = rng.randrange(1, min(6, n) + 1)
        cols = rng.randrange(1, min(6, n) + 1)
        root = rng.randrange(n)
        want = serial_bfs(n, adj, root)
        procs, messages, levels = run_2d(n, adj, offsets, rows, cols, root)
        for k, p in enumerate(procs):
            assert p.d == want, (
                f"n={n} grid={rows}x{cols} root={root}: processor {k} disagrees"
            )
        model = levels * (rows * cols) * ((cols - 1) + (rows - 1))
        assert messages == model, f"n={n} grid={rows}x{cols}: {messages} != {model}"


def test_degenerate_grids():
    # 1x1 never communicates; 1xP folds only; Px1 expands only.
    adj = [[1], [0, 2], [1]]
    offsets = [0, 1, 3, 4]
    for (rows, cols, expected_partners) in [(1, 1, 0), (1, 3, 2), (3, 1, 2)]:
        procs, messages, levels = run_2d(3, adj, offsets, rows, cols, 0)
        want = serial_bfs(3, adj, 0)
        assert all(p.d == want for p in procs)
        assert messages == levels * rows * cols * expected_partners
