"""Test collection guard: the L1/L2 tests need the JAX/Pallas toolchain
(and `hypothesis` for the randomized kernel suite). When a dependency is
missing, skip the affected module cleanly instead of erroring at import —
CI environments without the accelerator toolchain still get a green run.

Also puts `python/` on sys.path so `from compile...` imports resolve when
pytest is invoked from the repository root (`python -m pytest python/tests`).
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _missing(module: str) -> bool:
    return importlib.util.find_spec(module) is None

collect_ignore = []
if _missing("jax") or _missing("numpy"):
    # Everything in this suite exercises the JAX model/kernel/AOT layers.
    collect_ignore += ["test_kernel.py", "test_model.py", "test_aot.py"]
elif _missing("hypothesis"):
    # Only the randomized kernel suite needs hypothesis.
    collect_ignore += ["test_kernel.py"]
