"""Executable spec of the fault-injection + recovery model.

The Rust engine's fault layer (``fault/plan.rs``) injects a seeded,
fully explicit fault schedule at the Phase-2 exchange seam: drops and
corruptions are detected (frame checksum / missing frame) and re-sent
with exponential backoff, stragglers add pure delay, and every
recovery action is priced through the same interconnect model as
first-transmission traffic — so a tolerated fault changes *counters and
simulated time only*, never a distance. This suite pins the Python port
of that arithmetic: deterministic generation from a seed, the backoff
and retransmit pricing closed-form, inertness of faults that address
transfers the schedule never performs, fire-count budgets, the
unrecoverable paths (budget exhaustion, killed rank), and the headline
fault-equivalence invariant the CI-checked ``BENCH_engine.json``
``fault_recovery`` section records.
"""

import random

import pytest

import bench_protocol_port as bp


# ---------------------------------------------------------------------------
# Seeded generation
# ---------------------------------------------------------------------------


def test_generate_is_deterministic_and_in_range():
    a = bp.fault_plan_generate(23, 9, 4, 2, 16)
    b = bp.fault_plan_generate(23, 9, 4, 2, 16)
    assert a == b
    assert len(a["faults"]) == 9
    assert a["max_retries"] == 3 and a["backoff_us"] == 10
    for k, f in enumerate(a["faults"]):
        assert f["level"] < 4 and f["round"] < 2
        assert f["src"] < 16 and f["dst"] < 16
        assert f["kind"] == ["drop", "corrupt", "delay"][k % 3]
        if f["kind"] == "delay":
            assert f["delay_us"] == 25
        else:
            assert f["repeat"] == 1
    assert a != bp.fault_plan_generate(24, 9, 4, 2, 16)


def test_generate_draw_order_matches_splitmix_stream():
    # The generator draws level, round, src, dst in that order from one
    # SplitMix64 stream — the cross-language contract with Rust.
    sm = bp.SplitMix64(7)
    plan = bp.fault_plan_generate(7, 2, 5, 3, 8)
    for f in plan["faults"]:
        assert f["level"] == sm.next_u64() % 5
        assert f["round"] == sm.next_u64() % 3
        assert f["src"] == sm.next_u64() % 8
        assert f["dst"] == sm.next_u64() % 8


def test_plan_json_shape():
    plan = bp.fault_plan_generate(1, 3, 2, 2, 4)
    j = bp.fault_plan_json(plan)
    assert j["max_retries"] == 3 and j["backoff_us"] == 10
    assert [f["kind"] for f in j["faults"]] == ["drop", "corrupt", "delay"]
    for f in j["faults"]:
        assert set(f) >= {"level", "round", "kind", "fires", "src", "dst"}


# ---------------------------------------------------------------------------
# Pricing closed-forms
# ---------------------------------------------------------------------------


def test_backoff_is_exponential_and_clamped():
    plan = dict(max_retries=3, backoff_us=10, faults=[])
    assert bp.fault_backoff_seconds(plan, 1) == pytest.approx(10e-6)
    assert bp.fault_backoff_seconds(plan, 2) == pytest.approx(20e-6)
    assert bp.fault_backoff_seconds(plan, 5) == pytest.approx(160e-6)
    # Exponent clamp keeps hostile plans finite.
    assert bp.fault_backoff_seconds(plan, 1000) == pytest.approx(
        10e-6 * (1 << 20))


def test_retransmit_uses_pair_link_class():
    # Uniform topology: always the flat DGX-2 class.
    t = bp.retransmit_time(None, 0, 9, 25_000_000_000)
    assert t == pytest.approx(bp.DGX2["latency"] + 1.0)
    topo = bp.dgx2_cluster_topo(4)
    intra = bp.retransmit_time(topo, 0, 3, 1000)
    inter = bp.retransmit_time(topo, 0, 4, 1000)
    assert intra == pytest.approx(
        bp.DGX2["latency"] + 1000 / bp.DGX2["link_bw"])
    assert inter == pytest.approx(
        bp.ISLAND_UPLINK["latency"] + 1000 / bp.ISLAND_UPLINK["link_bw"])
    assert inter > intra


def test_drop_pricing_sums_backoff_plus_retransmit():
    plan = dict(max_retries=3, backoff_us=10, faults=[
        dict(level=0, round=0, src=0, dst=1, kind="drop", repeat=3,
             max_fires=0),
    ])
    inj = bp.FaultInjector(plan)
    rounds = [[(0, 1), (2, 3)]]
    payloads = [[500, 700]]
    r, rb, rec = inj.apply_level(0, rounds, payloads, None, 4)
    assert (r, rb) == (3, 1500)
    want = sum(bp.fault_backoff_seconds(plan, k) for k in [1, 2, 3])
    want += 3 * bp.retransmit_time(None, 0, 1, 500)
    assert rec == pytest.approx(want, rel=1e-12)


def test_delay_adds_pure_time_no_retries():
    plan = dict(max_retries=3, backoff_us=10, faults=[
        dict(level=2, round=0, src=1, dst=0, kind="delay", delay_us=40,
             max_fires=0),
    ])
    inj = bp.FaultInjector(plan)
    r, rb, rec = inj.apply_level(2, [[(1, 0)]], [[64]], None, 2)
    assert (r, rb) == (0, 0)
    assert rec == pytest.approx(40e-6)


# ---------------------------------------------------------------------------
# Inertness, budgets, unrecoverable paths
# ---------------------------------------------------------------------------


def test_unmatched_and_empty_transfers_are_inert():
    plan = dict(max_retries=3, backoff_us=10, faults=[
        # Wrong level, wrong round, absent pair, and an empty payload.
        dict(level=5, round=0, src=0, dst=1, kind="drop", repeat=1,
             max_fires=0),
        dict(level=0, round=7, src=0, dst=1, kind="drop", repeat=1,
             max_fires=0),
        dict(level=0, round=0, src=3, dst=0, kind="corrupt", repeat=1,
             max_fires=0),
        dict(level=0, round=0, src=2, dst=3, kind="drop", repeat=1,
             max_fires=0),
    ])
    inj = bp.FaultInjector(plan)
    r, rb, rec = inj.apply_level(0, [[(0, 1), (2, 3)]], [[100, 0]], None, 4)
    assert (r, rb, rec) == (0, 0, 0.0)
    assert inj.specs_matched() == 0


def test_max_fires_budget_makes_faults_transient():
    plan = dict(max_retries=3, backoff_us=10, faults=[
        dict(level=0, round=0, src=0, dst=1, kind="drop", repeat=1,
             max_fires=1),
    ])
    inj = bp.FaultInjector(plan)
    r1, _, _ = inj.apply_level(0, [[(0, 1)]], [[100]], None, 2)
    r2, _, _ = inj.apply_level(0, [[(0, 1)]], [[100]], None, 2)
    assert (r1, r2) == (1, 0)
    assert inj.specs_matched() == 1


def test_exhausted_budget_raises_instead_of_wrong_answer():
    plan = dict(max_retries=3, backoff_us=10, faults=[
        dict(level=0, round=0, src=0, dst=1, kind="corrupt", repeat=4,
             max_fires=0),
    ])
    inj = bp.FaultInjector(plan)
    with pytest.raises(RuntimeError, match="retry budget"):
        inj.apply_level(0, [[(0, 1)]], [[100]], None, 2)


def test_kill_rank_raises_rank_dead():
    plan = dict(max_retries=3, backoff_us=10, faults=[
        dict(level=1, round=0, src=2, dst=0, kind="kill", max_fires=1),
    ])
    inj = bp.FaultInjector(plan)
    # Level 0: no fault addressed, nothing happens.
    assert inj.apply_level(0, [[(0, 1)]], [[10]], None, 4) == (0, 0, 0.0)
    with pytest.raises(RuntimeError, match="rank 2 dead at level 1"):
        inj.apply_level(1, [[(0, 1)]], [[10]], None, 4)
    # max_fires=1: the replayed level sails past the transient kill.
    assert inj.apply_level(1, [[(0, 1)]], [[10]], None, 4) == (0, 0, 0.0)


# ---------------------------------------------------------------------------
# Fault equivalence on real traversals
# ---------------------------------------------------------------------------


def test_injection_is_counter_only_on_real_batches():
    # Injection happens at the exchange seam after payloads are priced:
    # distances and per-level byte/message counters must be identical to
    # the fault-free run, while the recovery counters are exactly the
    # closed-form sum over matched faults.
    rng = random.Random(0xFA017)
    for _ in range(6):
        g = bp.uniform_random(60 + rng.randrange(80), 3, rng.randrange(1 << 40))
        nodes, fanout = 8, 2
        roots = [rng.randrange(g.n) for _ in range(5)]
        direction = rng.choice(["topdown", "bottomup", "diropt"])
        free = bp.run_batch(g, nodes, fanout, roots, direction)
        faulted = bp.run_batch(g, nodes, fanout, roots, direction)
        assert faulted["dist"] == free["dist"]
        rounds = bp.butterfly_schedule(nodes, fanout)
        plan = bp.fault_plan_generate(rng.randrange(1 << 30), 5,
                                      len(free["levels"]), len(rounds), nodes)
        inj = bp.FaultInjector(plan)
        total = [0, 0, 0.0]
        for lvl in faulted["levels"]:
            r, rb, rec = inj.apply_level(lvl["level"], rounds,
                                         lvl["payloads"], None, nodes)
            total[0] += r
            total[1] += rb
            total[2] += rec
        assert total[0] == total[1] == 0 or total[2] > 0.0
        for lf, lv in zip(free["levels"], faulted["levels"]):
            assert lf["bytes"] == lv["bytes"]
            assert lf["messages"] == lv["messages"]


def test_committed_bench_schedule_fires():
    # The committed BENCH_engine.json fault schedule (seed 43) must match
    # live transfers and force at least one retransmission — the same
    # invariant the Rust acceptance pass enforces on the artifact.
    p = bp.PROTOCOL
    scale = max(p["kron_scale"] + p["scale_delta"], 4)
    g = bp.kronecker(scale, p["kron_edge_factor"], p["kron_seed"])
    fr = bp.fault_recovery_report(g)
    assert fr["equal_distances"] is True
    assert fr["faulted"]["matched"] >= 1
    assert fr["faulted"]["retries"] >= 1
    assert fr["faulted"]["retry_bytes"] >= 1
    assert fr["faulted"]["recovery_time"] > 0.0
    assert fr["overhead_ratio"] > 1.0
    assert fr["faulted"]["sim_seconds"] == pytest.approx(
        fr["fault_free"]["sim_seconds"] + fr["faulted"]["recovery_time"])
