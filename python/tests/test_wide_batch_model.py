"""Pure-python spec of the const-generic wide lane masks (this PR).

Drives the line-for-line engine port in ``bench_protocol_port`` — the
same code that generates the committed ``BENCH_engine.json`` — through
the wide-batch semantics the Rust engine must honor:

* batches crossing every lane-word boundary (W ∈ {2, 4, 8}) stay
  bit-identical to the serial per-root oracle, in 1D and 2D, under all
  three direction policies;
* one wide batch equals its 64-root chunks lane for lane, while running
  strictly fewer sync rounds and (via the cohort-factored negotiated
  pricing) no more exchange bytes;
* the configured width floor (``width_words``) changes pricing only —
  never distances — and the W = 1 pricing identities hold exactly
  (``word`` statistics collapse onto the counts), which is what keeps
  the committed single-word counters stable across this PR.

No jax/hypothesis needed — runs everywhere CI runs.
"""

import bench_protocol_port as bp


def small_graph(seed=0xFACE, n=120, ef=4):
    return bp.uniform_random(n, ef, seed)


def test_wide_batches_match_serial_in_both_modes():
    g = small_graph()
    n = g.n
    for width in [70, 130, 260]:
        roots = [(i * 11 + 3) % n for i in range(width)]
        want = [bp.serial_bfs(g, r) for r in roots]
        for kw in [dict(), dict(mode="2d", grid=(2, 2))]:
            for d in ["topdown", "bottomup", "diropt"]:
                m = bp.run_batch(g, 4, 2, roots, d,
                                 width_words=bp.words_for_lanes(width), **kw)
                assert m["lane_words"] == bp.words_for_lanes(width)
                for lane in range(width):
                    assert m["dist"][lane] == want[lane], (width, kw, d, lane)


def test_chunked_equals_wide_and_amortizes():
    g = small_graph(seed=0xBEAD, n=150)
    width = 200
    roots = [(i * 7 + 1) % g.n for i in range(width)]
    for kw in [dict(), dict(mode="2d", grid=(2, 3))]:
        wide = bp.run_batch(g, 6 if kw else 4, 2, roots, "topdown",
                            width_words=4, **kw)
        rounds = bytes_ = 0
        for k in range(0, width, 64):
            cm = bp.run_batch(g, 6 if kw else 4, 2, roots[k:k + 64],
                              "topdown", **kw)
            assert cm["lane_words"] == 1
            for j, lane_dist in enumerate(cm["dist"]):
                assert lane_dist == wide["dist"][k + j], (kw, k + j)
            rounds += cm["sync_rounds"]
            bytes_ += sum(l["bytes"] for l in cm["levels"])
        assert wide["sync_rounds"] < rounds, kw
        assert sum(l["bytes"] for l in wide["levels"]) <= bytes_, kw


def test_width_floor_changes_pricing_never_distances():
    g = small_graph(seed=0x1DEA)
    roots = [(i * 5) % g.n for i in range(20)]
    narrow = bp.run_batch(g, 4, 2, roots, "topdown", width_words=1)
    wide = bp.run_batch(g, 4, 2, roots, "topdown", width_words=8)
    assert narrow["lane_words"] == 1 and wide["lane_words"] == 8
    assert narrow["dist"] == wide["dist"]
    assert narrow["reached_pairs"] == wide["reached_pairs"]
    nb = sum(l["bytes"] for l in narrow["levels"])
    wb = sum(l["bytes"] for l in wide["levels"])
    # The cohort-factored negotiation caps the wide format at the
    # single-word (chunk-equivalent) cost; with one 64-lane cohort active
    # the two prices coincide exactly.
    assert wb == nb


def test_w1_pricing_identities():
    # At words == 1 the word-sparse formulas collapse to the original
    # single-word pricing (the committed-counter stability guarantee).
    for (e, dv, dm, al, nv) in [(10, 8, 3, 7, 640), (500, 400, 2, 64, 2048)]:
        legacy = min(e * 12, dm * 12 + e * 4,
                     -(-nv // 64) * 8 + dv * 8, (1 + al) * -(-nv // 64) * 8)
        got = bp.mask_delta_bytes(e, dv, dm, al, nv, 1, 1, e, dv, dm)
        assert got == legacy, (e, dv, dm, al, nv)
