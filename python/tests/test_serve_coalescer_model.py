"""Executable spec of the serve-mode coalescer and its throughput sim
(PR 6): drives the Python mirror of `rust/src/serve/coalescer.rs` (the
pack/deadline logic behind the `serve` subcommand) plus the
`serve_sim_mode` discrete-event loop from `bench_protocol_port.py`.

The Rust unit tests pin the same behaviors on the real type; this file
pins the mirror, and — with the engine stubbed to a constant service
time — checks the event loop's dispatch schedule against hand-computed
timelines, something the end-to-end sim (real engine, 256 requests)
is too slow and too opaque to do.

No jax/hypothesis needed — runs everywhere CI runs.
"""

import math

import bench_protocol_port as bp


# --------------------------------------------------------------------------
# Coalescer dispatch contract (mirror of serve/coalescer.rs unit tests)
# --------------------------------------------------------------------------


def test_lone_request_dispatches_on_window_expiry_as_width_1():
    c = bp.Coalescer(window_us=200, max_batch=64, depth=8)
    assert c.due_at() is None
    assert c.try_push(1_000, None, 7)
    assert c.due_at() == 1_200
    assert not c.due(1_199)
    assert c.due(1_200)
    batch = c.take_batch()
    assert [p[2] for p in batch] == [7]
    assert batch[0][0] == 1_000
    assert len(c) == 0


def test_batch_full_beats_window_expiry():
    c = bp.Coalescer(window_us=1_000, max_batch=4, depth=16)
    for i, t in enumerate([10, 20, 30, 40]):
        assert c.try_push(t, None, i)
    # Full at the arrival of the 4th request — long before the oldest
    # window would expire at t=1_010.
    assert c.due_at() == 40
    assert c.due(40)
    assert [p[2] for p in c.take_batch()] == [0, 1, 2, 3]


def test_take_batch_is_fifo_and_leaves_the_remainder():
    c = bp.Coalescer(window_us=100, max_batch=2, depth=16)
    for i, t in enumerate([1, 2, 3, 4, 5]):
        assert c.try_push(t, None, i)
    assert [p[2] for p in c.take_batch()] == [0, 1]
    assert [p[2] for p in c.take_batch()] == [2, 3]
    # The straggler's window now drives the next dispatch.
    assert c.due_at() == 105
    assert [p[2] for p in c.take_batch()] == [4]
    assert c.due_at() is None


def test_admission_is_bounded_and_refused_past_depth():
    c = bp.Coalescer(window_us=100, max_batch=64, depth=2)
    assert c.try_push(0, None, "a")
    assert c.try_push(1, None, "b")
    assert not c.try_push(2, None, "c")
    assert len(c) == 2
    # Draining frees capacity again.
    c.take_batch()
    assert c.try_push(3, None, "c")


def test_expire_removes_only_past_deadline_requests_in_order():
    c = bp.Coalescer(window_us=1_000, max_batch=64, depth=16)
    c.try_push(0, 50, 0)
    c.try_push(1, None, 1)
    c.try_push(2, 40, 2)
    c.try_push(3, 500, 3)
    expired = c.expire(50)
    assert [p[2] for p in expired] == [0, 2]
    assert [p[2] for p in c.take_batch()] == [1, 3]


def test_window_zero_max_batch_one_degenerates_to_no_coalescing():
    # The baseline mode of the serve_throughput protocol section.
    c = bp.Coalescer(window_us=0, max_batch=1, depth=64)
    assert c.try_push(100, None, 1)
    assert c.try_push(100, None, 2)
    assert c.due_at() == 100
    assert len(c.take_batch()) == 1
    assert len(c.take_batch()) == 1


# --------------------------------------------------------------------------
# nearest-rank percentiles (mirror of serve/metrics.rs)
# --------------------------------------------------------------------------


def test_nearest_rank_percentiles():
    assert bp.nearest_rank_us([], 50.0) == 0
    assert bp.nearest_rank_us([7], 50.0) == 7
    assert bp.nearest_rank_us([7], 99.0) == 7
    xs = list(range(1, 101))  # 1..=100
    assert bp.nearest_rank_us(xs, 50.0) == 50
    assert bp.nearest_rank_us(xs, 99.0) == 99
    assert bp.nearest_rank_us(xs, 100.0) == 100
    # rank clamps to [1, n] even for tiny p.
    assert bp.nearest_rank_us(xs, 0.0) == 1


# --------------------------------------------------------------------------
# serve_sim_mode event loop against hand-computed timelines
# --------------------------------------------------------------------------

def _stub_engine(monkeypatch, requests, gap_us, queue_depth,
                 service_seconds):
    """Shrink the protocol load point and pin the engine's simulated
    clock to a constant, so the dispatch schedule is hand-checkable."""
    monkeypatch.setitem(bp.PROTOCOL, "serve_requests", requests)
    monkeypatch.setitem(bp.PROTOCOL, "serve_gap_us", gap_us)
    monkeypatch.setitem(bp.PROTOCOL, "serve_queue_depth", queue_depth)

    def fake_run_batch(g, nodes, fanout, roots, direction, **kw):
        return {
            "levels": [{"sim_compute": service_seconds, "sim_comm": 0.0,
                        "messages": 0, "bytes": 0, "edges": 0,
                        "frontier": 0, "level": 0, "direction": direction}],
            "sync_rounds": 1,
            "reached_pairs": len(roots),
            "dist": [],
            "graph_edges": 0,
            "lane_words": 1,
        }

    monkeypatch.setattr(bp, "run_batch", fake_run_batch)
    # The quantization the sim applies, computed the same way.
    return math.ceil(service_seconds * 1e6)


def _tiny_graph():
    return bp.uniform_random(60, 3, 0xBEEF)


def test_sim_uncontended_baseline_has_pure_service_latency(monkeypatch):
    # Service shorter than the arrival gap: the width-1 server never
    # queues, so every latency is exactly the service time.
    svc = _stub_engine(monkeypatch, requests=10, gap_us=30, queue_depth=8,
                       service_seconds=12e-6)
    m = bp.serve_sim_mode(_tiny_graph(), window_us=0, max_batch=1)
    assert m["completed"] == 10 and m["rejected"] == 0
    assert m["p50_us"] == svc and m["p99_us"] == svc
    assert m["mean_latency_us"] == float(svc)
    assert m["batches"] == 10 and m["mean_width"] == 1.0
    # Last arrival at 9*30, dispatched immediately, done svc later.
    assert m["span_us"] == 9 * 30 + svc


def test_sim_batch_full_dispatch_schedule(monkeypatch):
    # window 100 > 3*gap, max_batch 4: every batch fills at its 4th
    # arrival and dispatches there (batch-full beats window expiry).
    svc = _stub_engine(monkeypatch, requests=8, gap_us=30, queue_depth=64,
                       service_seconds=12e-6)
    m = bp.serve_sim_mode(_tiny_graph(), window_us=100, max_batch=4)
    assert m["batches"] == 2 and m["max_width"] == 4
    # Batch 1: arrivals 0,30,60,90 -> start 90; batch 2: arrivals
    # 120..210 -> start 210 (worker long free by then).
    finish1, finish2 = 90 + svc, 210 + svc
    assert m["span_us"] == finish2
    lat = sorted([finish1 - t for t in (0, 30, 60, 90)]
                 + [finish2 - t for t in (120, 150, 180, 210)])
    assert m["completed"] == 8
    assert m["p50_us"] == bp.nearest_rank_us(lat, 50.0)
    assert m["p99_us"] == lat[-1]
    assert m["mean_latency_us"] == sum(lat) / 8


def test_sim_straggler_dispatches_alone_on_window_expiry(monkeypatch):
    # 5 requests, max_batch 4: the 5th never sees a full batch and must
    # go out alone once its window runs out.
    svc = _stub_engine(monkeypatch, requests=5, gap_us=30, queue_depth=64,
                       service_seconds=12e-6)
    m = bp.serve_sim_mode(_tiny_graph(), window_us=100, max_batch=4)
    assert m["batches"] == 2
    assert m["max_width"] == 4
    # Straggler arrives at 120, window expires at 220, done svc later.
    assert m["span_us"] == 220 + svc


def test_sim_overload_rejects_and_accounting_closes(monkeypatch):
    # Service far above the gap with a depth-2 queue: the width-1 server
    # falls behind and sheds load, but every request is accounted for.
    _stub_engine(monkeypatch, requests=20, gap_us=30, queue_depth=2,
                 service_seconds=500e-6)
    m = bp.serve_sim_mode(_tiny_graph(), window_us=0, max_batch=1)
    assert m["rejected"] > 0
    assert m["completed"] + m["rejected"] + m["timed_out"] == m["offered"]
    assert m["p50_us"] <= m["p99_us"]


def test_sim_is_deterministic_and_coalescing_pays_under_load(monkeypatch):
    # At a load point that overloads the width-1 server, coalescing must
    # lift qps and cut p50 — the acceptance invariant of the committed
    # BENCH_engine.json section, replayed at stub scale.
    _stub_engine(monkeypatch, requests=40, gap_us=30, queue_depth=16,
                 service_seconds=100e-6)
    g = _tiny_graph()
    base = bp.serve_sim_mode(g, window_us=0, max_batch=1)
    coal = bp.serve_sim_mode(g, window_us=240, max_batch=16)
    assert base == bp.serve_sim_mode(g, window_us=0, max_batch=1)
    assert coal == bp.serve_sim_mode(g, window_us=240, max_batch=16)
    assert base["rejected"] > 0
    assert coal["rejected"] == 0
    assert coal["qps"] > base["qps"]
    assert coal["p50_us"] < base["p50_us"]
    assert base["mean_width"] == 1.0
    assert coal["mean_width"] > 1.0
