"""Executable spec of the `.bbfs` v2 store codec (PR 7): the Python
mirror of `rust/src/graph/store/{varint,writer,loader}.rs` from
`bench_protocol_port.py`.

The committed `BENCH_engine.json` `storage` section cross-validates the
Rust codec against this mirror byte-for-byte (sizes + fingerprint), so
these tests are the fast, isolated half of that contract: varint edge
values, container layout invariants, round-trips across block sizes and
degenerate graphs, relabeling algebra, and the decode-counter formulas
behind the warm-start claim.

No jax/hypothesis needed — runs everywhere CI runs.
"""

import bench_protocol_port as bp


def _roundtrip(g, **kw):
    img, old_id = bp.encode_store(g, **kw)
    dec, perm = bp.decode_store(img)
    assert perm == old_id
    return img, dec, perm


# --------------------------------------------------------------------------
# Varints
# --------------------------------------------------------------------------


def test_varint_round_trips_edge_values():
    for v in [0, 1, 127, 128, 129, 16383, 16384, 2097151,
              (1 << 32) - 1, (1 << 63), (1 << 64) - 1]:
        buf = bytearray()
        bp.encode_varint(v, buf)
        assert len(buf) <= bp.MAX_VARINT_LEN
        got, pos = bp.decode_varint(bytes(buf), 0)
        assert (got, pos) == (v, len(buf))


def test_varint_single_byte_below_128():
    for v in range(128):
        buf = bytearray()
        bp.encode_varint(v, buf)
        assert bytes(buf) == bytes([v])


# --------------------------------------------------------------------------
# Container round-trips
# --------------------------------------------------------------------------


def test_roundtrip_uniform_random_across_block_sizes():
    g = bp.uniform_random(300, 5, 71)
    for bs in [1, 2, 3, 64, 1024]:
        _, dec, _ = _roundtrip(g, block_size=bs)
        assert dec.offsets == g.offsets and dec.edges == g.edges


def test_roundtrip_degenerate_graphs():
    for g in [
        bp.build_undirected(0, []),          # empty
        bp.build_undirected(1, []),          # single isolated vertex
        bp.build_undirected(3, [(0, 0)]),    # self-loop only: no edges kept
        bp.build_undirected(5, [(0, 1), (0, 1), (1, 0)]),  # duplicates
    ]:
        for bs in [1, 1024]:
            _, dec, _ = _roundtrip(g, block_size=bs)
            assert dec.n == g.n
            assert dec.offsets == g.offsets and dec.edges == g.edges


def test_roundtrip_weblike_relabeled():
    g = bp.weblike(600, 7, 0xB0B0_0006, strand_frac=0.18, strand_len=9)
    img, dec, perm = _roundtrip(g, relabel=True, block_size=128)
    # Stored permutation is a bijection, and the payload is the graph
    # permuted by it.
    assert sorted(perm) == list(range(g.n))
    new_id = [0] * g.n
    for new, old in enumerate(perm):
        new_id[old] = new
    rg = bp.apply_relabeling(g, new_id)
    assert dec.offsets == rg.offsets and dec.edges == rg.edges
    # Degree sort: degrees are non-increasing in the stored id space.
    degs = [dec.degree(v) for v in range(dec.n)]
    assert all(degs[i] >= degs[i + 1] for i in range(len(degs) - 1))


def test_relabeled_bfs_unmaps_to_original_distances():
    g = bp.weblike(400, 5, 13, strand_frac=0.1, strand_len=4)
    _, dec, perm = _roundtrip(g, relabel=True)
    new_id = [0] * g.n
    for new, old in enumerate(perm):
        new_id[old] = new
    for root in [0, 7, 399]:
        want = bp.serial_bfs(g, root)
        got_new = bp.serial_bfs(dec, new_id[root])
        assert [got_new[new_id[v]] for v in range(g.n)] == want


# --------------------------------------------------------------------------
# Layout invariants + fingerprint
# --------------------------------------------------------------------------


def test_header_layout_and_alignment():
    g = bp.uniform_random(200, 4, 11)
    img, _ = bp.encode_store(g, block_size=64)
    assert img[0:8] == bp.V2_MAGIC
    assert int.from_bytes(img[8:12], "little") == 2
    n = int.from_bytes(img[16:24], "little")
    nb = int.from_bytes(img[36:40], "little")
    assert n == 200 and nb == -(-200 // 64)
    data_off = int.from_bytes(img[56:64], "little")
    assert data_off % bp.DATA_ALIGN == 0
    assert int.from_bytes(img[64:72], "little") == len(img)
    # Index sentinel closes the data section exactly.
    at = bp.HEADER_LEN + 16 * nb
    assert int.from_bytes(img[at:at + 8], "little") == len(img) - data_off
    assert int.from_bytes(img[at + 8:at + 16], "little") == g.num_edges()


def test_fingerprint_covers_header_index_perm_but_not_data():
    g = bp.uniform_random(150, 3, 5)
    img, _ = bp.encode_store(g)
    fp = bp.store_fingerprint(img)
    # Flipping a data byte leaves the fingerprint unchanged (it pins the
    # header/index/permutation, which is what a plan cache depends on) …
    data_off = int.from_bytes(img[56:64], "little")
    tail = bytearray(img)
    tail[data_off] ^= 0xFF
    assert bp.store_fingerprint(bytes(tail)) == fp
    # … while flipping an index byte moves it.
    head = bytearray(img)
    head[bp.HEADER_LEN + 3] ^= 0xFF
    assert bp.store_fingerprint(bytes(head)) != fp


def test_compression_beats_v1_twofold_on_weblike():
    g = bp.weblike(1024, 12, 0xB0B0_0006, strand_frac=0.18, strand_len=9)
    img, _ = bp.encode_store(g)
    assert bp.v1_snapshot_bytes(g) / len(img) >= 2.0


# --------------------------------------------------------------------------
# Warm-start decode-counter arithmetic
# --------------------------------------------------------------------------


def test_materialize_counters_match_brute_force():
    g = bp.uniform_random(500, 4, 23)
    bs = 64
    cuts = bp.balanced_cuts_from_prefix(g.offsets, 7)
    deg, edges, blocks = bp.materialize_counters(g.offsets, cuts, g.n, bs)
    # Brute force: replay the loader's per-part block walk.
    bdeg = bedges = bblocks = 0
    for i in range(len(cuts) - 1):
        lo, hi = cuts[i], cuts[i + 1]
        for b in range(lo // bs, -(-hi // bs)):
            blo, bhi = b * bs, min((b + 1) * bs, g.n)
            bblocks += 1
            bdeg += bhi - blo
            bedges += sum(g.degree(v) for v in range(blo, min(bhi, hi)))
    assert (deg, edges, blocks) == (bdeg, bedges, bblocks)


def test_single_block_store_counts_whole_graph_once_per_part():
    g = bp.uniform_random(100, 3, 9)
    cuts = bp.balanced_cuts_from_prefix(g.offsets, 4)
    deg, edges, blocks = bp.materialize_counters(g.offsets, cuts, g.n, 1024)
    # One block: every part decodes it fully up to its own hi.
    assert blocks == 4
    assert deg == 4 * g.n
    assert edges == sum(g.offsets[hi] for hi in cuts[1:])
