"""Line-for-line Python port of the `bench-protocol` pipeline.

This is the generation/validation tool behind the committed
``BENCH_engine.json``: a faithful port of the Rust crate's PRNG
(SplitMix64 -> xoshiro256**), the Graph500 Kronecker generator + ETL,
the 1D edge-balanced partition, the butterfly schedule, the batched
MS-BFS engine with the direction-optimizing state machine (top-down /
bottom-up / alpha-beta), the negotiated mask-delta payload pricing, and
the DGX-2 interconnect/device timing models, and (v3) the serve-mode
request coalescer with its deterministic open-loop throughput sim.
Integer counters reproduce the Rust engine exactly; simulated-clock
floats reproduce it to ~1e-15 (the Rust checker compares floats with
1e-6 relative tolerance). v6 adds the fault-recovery model
(fault/plan.rs): the seeded fault schedule, the detect -> retry ->
backoff pricing, and the committed ``fault_recovery`` bench section.
v7 adds the mask-kernel work model (bfs/kernels.rs + the two-stage
bottom-up sweep/probe of coordinator/backend.rs): deterministic
words_touched / words_skipped / dispatches / dispatch_max_work counters
for the scalar and chunked kernel shapes, LRB degree-binned probe
dispatch, and the committed ``kernel_ablation`` bench section.

The canonical way to regenerate the artifact is the Rust CLI::

    cargo run --release -- bench-protocol --out BENCH_engine.json

This port exists so the artifact can be produced and cross-checked in
environments without a Rust toolchain, and doubles as an executable
spec: ``python python/bench_protocol_port.py --selftest`` sweeps the
batched engine against a serial BFS oracle across random configs and
direction policies before writing anything.
"""

import argparse
import bisect
import json
import math
import sys

MASK64 = (1 << 64) - 1
INF = 2**32 - 1


# --------------------------------------------------------------------------
# PRNG (util/prng.rs)
# --------------------------------------------------------------------------


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Xoshiro256StarStar:
    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_below(self, bound):
        assert bound > 0
        x = self.next_u64()
        m = x * bound
        lo = m & MASK64
        if lo < bound:
            t = ((1 << 64) - bound) % bound
            while lo < t:
                x = self.next_u64()
                m = x * bound
                lo = m & MASK64
        return m >> 64

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.next_below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


# --------------------------------------------------------------------------
# Graph generation + ETL (graph/gen/kronecker.rs, graph/builder.rs)
# --------------------------------------------------------------------------


class Csr:
    def __init__(self, n, arcs):
        """`arcs` must already be clean (symmetrized, deduped, sorted)."""
        self.n = n
        self.offsets = [0] * (n + 1)
        self.edges = [v for (_, v) in arcs]
        for (u, _) in arcs:
            self.offsets[u + 1] += 1
        for i in range(n):
            self.offsets[i + 1] += self.offsets[i]

    def num_edges(self):
        return len(self.edges)

    def neighbors(self, v):
        return self.edges[self.offsets[v]:self.offsets[v + 1]]

    def degree(self, v):
        return self.offsets[v + 1] - self.offsets[v]


def build_undirected(n, raw_arcs):
    arcs = []
    for (u, v) in raw_arcs:
        if u == v:
            continue
        arcs.append((u, v))
        arcs.append((v, u))
    arcs.sort()
    dedup = []
    for a in arcs:
        if not dedup or dedup[-1] != a:
            dedup.append(a)
    return Csr(n, dedup)


def kronecker(scale, edge_factor, seed):
    """Graph500 defaults: A,B,C = .57,.19,.19, noise 0, permuted ids."""
    n = 1 << scale
    m = n * edge_factor
    rng = Xoshiro256StarStar(seed)
    ids = list(range(n))
    rng.shuffle(ids)
    raw = []
    for _ in range(m):
        u = v = 0
        for lvl in range(scale):
            r = rng.next_f64()
            bit = 1 << (scale - 1 - lvl)
            if r < 0.57:
                pass
            elif r < 0.57 + 0.19:
                v |= bit
            elif r < 0.57 + 0.19 + 0.19:
                u |= bit
            else:
                u |= bit
                v |= bit
        raw.append((ids[u], ids[v]))
    return build_undirected(n, raw)


def uniform_random(n, edge_factor, seed):
    rng = Xoshiro256StarStar(seed)
    raw = []
    for _ in range(n * edge_factor):
        u = rng.next_below(n)
        v = rng.next_below(n)
        raw.append((u, v))
    return build_undirected(n, raw)


def sample_batch_roots(g, width, seed):
    rng = Xoshiro256StarStar(seed)
    roots = []
    while len(roots) < width:
        v = rng.next_below(g.n)
        for _ in range(8):
            if g.degree(v) > 0:
                break
            v = rng.next_below(g.n)
        if g.degree(v) == 0:
            for off in range(1, g.n):
                u = (v + off) % g.n
                if g.degree(u) > 0:
                    v = u
                    break
        roots.append(v)
    return roots


# --------------------------------------------------------------------------
# Partition + schedule (partition/one_d.rs, partition/two_d.rs,
# comm/butterfly.rs, comm/fold_expand.rs)
# --------------------------------------------------------------------------


def balanced_cuts_from_prefix(prefix, parts):
    """Port of one_d.rs::balanced_cuts_from_prefix (shared greedy)."""
    n = len(prefix) - 1
    total = float(prefix[n])
    cuts, v = [0], 0
    for p in range(1, parts):
        target = total * p / parts
        max_v = n - (parts - p)
        while v < max_v and prefix[v + 1] < target:
            v += 1
        v = min(max(v, cuts[-1] + 1), max_v)
        cuts.append(v)
    cuts.append(n)
    return cuts


def partition_1d_cuts(g, parts):
    return balanced_cuts_from_prefix(g.offsets, parts)


def node_layout(g, nodes, mode, grid):
    """Per-node (lo, hi) owned row range + block adjacency.

    1D: edge-balanced row slabs, full adjacency (``adj`` entry None).
    2D (``grid = (rows, cols)``): checkerboard blocks — edge-balanced row
    cuts × in-edge-balanced column cuts (two_d.rs); node ``i·cols + j``
    owns rows ``row_range(i)`` with neighbors filtered to
    ``col_range(j)``.
    """
    if mode == "1d":
        cuts = partition_1d_cuts(g, nodes)
        return [(cuts[i], cuts[i + 1]) for i in range(nodes)], [None] * nodes
    rows, cols = grid
    assert rows * cols == nodes
    row_cuts = balanced_cuts_from_prefix(g.offsets, rows)
    in_prefix = [0] * (g.n + 1)
    for w in g.edges:
        in_prefix[w + 1] += 1
    for i in range(g.n):
        in_prefix[i + 1] += in_prefix[i]
    col_cuts = balanced_cuts_from_prefix(in_prefix, cols)
    ranges, adjs = [], []
    for r in range(nodes):
        i, j = r // cols, r % cols
        lo, hi = row_cuts[i], row_cuts[i + 1]
        clo, chi = col_cuts[j], col_cuts[j + 1]
        adj = []
        for v in range(lo, hi):
            ns = g.neighbors(v)
            s = bisect.bisect_left(ns, clo)
            e = bisect.bisect_left(ns, chi)
            adj.append(ns[s:e])
        ranges.append((lo, hi))
        adjs.append(adj)
    return ranges, adjs


def fold_expand_schedule(rows, cols):
    """Port of comm/fold_expand.rs (transfer order preserved)."""
    rank = lambda i, j: i * cols + j
    rounds = []
    if cols > 1:
        rounds.append([
            (rank(i, j), rank(i, j2))
            for i in range(rows)
            for j in range(cols)
            for j2 in range(cols)
            if j2 != j
        ])
    if rows > 1:
        rounds.append([
            (rank(i, j), rank(i2, j))
            for i in range(rows)
            for j in range(cols)
            for i2 in range(rows)
            if i2 != i
        ])
    return rounds


def butterfly_schedule(cn, fanout):
    radix = max(fanout, 2)
    depth, span = 0, 1
    while span < cn:
        span *= radix
        depth += 1
    rounds = []
    for i in range(depth):
        stride = radix**i
        rnd = []
        for gdst in range(cn):
            digit = (gdst // stride) % radix
            base = gdst - digit * stride
            srcs = []
            for j in range(radix):
                if j == digit:
                    continue
                partner = base + j * stride
                holder = cn - 1 if partner >= cn else partner
                if holder != gdst and holder not in srcs:
                    srcs.append(holder)
            for src in srcs:
                rnd.append((src, gdst))
        rnd = sorted(set(rnd))
        rounds.append(rnd)
    return rounds


def hierarchical_schedule(islands, per_island, fanout):
    """Port of comm/hierarchical.rs::GridOfIslands (transfer order
    preserved): butterfly inside each island, butterfly across island
    representatives, then a one-round rep -> island broadcast."""
    rounds = []
    for rnd in butterfly_schedule(per_island, fanout):
        out = []
        for isl in range(islands):
            base = isl * per_island
            out.extend((base + s, base + d) for (s, d) in rnd)
        out.sort()
        rounds.append(out)
    for rnd in butterfly_schedule(islands, fanout):
        rounds.append(sorted((s * per_island, d * per_island) for (s, d) in rnd))
    if islands > 1 and per_island > 1:
        rounds.append([
            (isl * per_island, isl * per_island + local)
            for isl in range(islands)
            for local in range(1, per_island)
        ])
    return rounds


# --------------------------------------------------------------------------
# Timing models (net/model.rs, net/sim.rs)
# --------------------------------------------------------------------------

DGX2 = dict(link_bw=25.0e9, ports=6, latency=2.0e-6)
ISLAND_UPLINK = dict(link_bw=2.5e9, ports=2, latency=20.0e-6)
V100 = dict(edge_rate=22.0e9, level_overhead=12.0e-6, bu_factor=3.0)


def dgx2_cluster_topo(per_island):
    """Port of net/model.rs::TopologyModel::dgx2_cluster (10:1 ratio)."""
    return dict(name="dgx2-cluster", per_island=max(per_island, 1),
                intra=dict(DGX2), inter=dict(ISLAND_UPLINK))


def level_time(edges, bottom_up):
    f = V100["bu_factor"] if bottom_up else 1.0
    return V100["level_overhead"] + edges * f / V100["edge_rate"]


def simulate_schedule(rounds, payloads, cn):
    """Switched (NVSwitch) fabric — mirrors net/sim.rs exactly."""
    ports = float(DGX2["ports"])
    node_bw = DGX2["link_bw"] * DGX2["ports"]
    total_bytes = total_msgs = 0
    round_times = []
    for ri, rnd in enumerate(rounds):
        send_b = [0] * cn
        recv_b = [0] * cn
        send_m = [0] * cn
        recv_m = [0] * cn
        max_p = [0] * cn
        rbytes = 0
        for ti, (src, dst) in enumerate(rnd):
            b = payloads[ri][ti]
            send_b[src] += b
            recv_b[dst] += b
            send_m[src] += 1
            recv_m[dst] += 1
            max_p[src] = max(max_p[src], b)
            max_p[dst] = max(max_p[dst], b)
            rbytes += b
        total_bytes += rbytes
        total_msgs += len(rnd)
        t_round = 0.0
        for gg in range(cn):
            setup_send = DGX2["latency"] * math.ceil(send_m[gg] / ports)
            setup_recv = DGX2["latency"] * math.ceil(recv_m[gg] / ports)

            def makespan(msgs, byts):
                slots = math.ceil(msgs / ports)
                return max(byts / node_bw, slots * max_p[gg] / DGX2["link_bw"])

            t = max(setup_send + makespan(send_m[gg], send_b[gg]),
                    setup_recv + makespan(recv_m[gg], recv_b[gg]))
            t_round = max(t_round, t)
        round_times.append(t_round)
    return round_times, total_bytes, total_msgs


def price_round(num_endpoints, transfers, net):
    """Port of net/sim.rs::price_round — one link class, switched fabric.

    ``transfers`` is (src, dst, bytes) triples in endpoint id space
    (ranks for the intra class, islands for the inter class)."""
    send_b = [0] * num_endpoints
    recv_b = [0] * num_endpoints
    send_m = [0] * num_endpoints
    recv_m = [0] * num_endpoints
    max_p = [0] * num_endpoints
    for (src, dst, b) in transfers:
        send_b[src] += b
        recv_b[dst] += b
        send_m[src] += 1
        recv_m[dst] += 1
        max_p[src] = max(max_p[src], b)
        max_p[dst] = max(max_p[dst], b)
    ports = float(net["ports"])
    node_bw = net["link_bw"] * net["ports"]
    alloc_over = net.get("alloc", 0.0)
    t_round = 0.0
    for g in range(num_endpoints):
        setup_send = net["latency"] * math.ceil(send_m[g] / ports)
        setup_recv = net["latency"] * math.ceil(recv_m[g] / ports)

        def makespan(msgs, byts):
            slots = math.ceil(msgs / ports)
            return max(byts / node_bw, slots * max_p[g] / net["link_bw"])

        t = max(setup_send + makespan(send_m[g], send_b[g]),
                setup_recv + makespan(recv_m[g], recv_b[g]))
        t_round = max(t_round, t + alloc_over * recv_m[g])
    return t_round


def simulate_topology(rounds, payloads, cn, topo):
    """Port of net/sim.rs::simulate_topology — two-class clustered
    pricing. Intra transfers contend per rank under ``topo['intra']``,
    inter transfers are re-addressed to their island endpoints and
    contend per island under ``topo['inter']`` (the classes overlap, so
    a round costs the max of the two). Returns ``(round_times, totals)``
    with the per-class byte/message split."""
    per_island = topo["per_island"]
    num_islands = -(-cn // per_island)
    tot = dict(bytes=0, messages=0, intra_bytes=0, intra_messages=0,
               inter_bytes=0, inter_messages=0)
    round_times = []
    for ri, rnd in enumerate(rounds):
        intra, inter = [], []
        for ti, (src, dst) in enumerate(rnd):
            b = payloads[ri][ti]
            tot["bytes"] += b
            if src // per_island == dst // per_island:
                tot["intra_bytes"] += b
                tot["intra_messages"] += 1
                intra.append((src, dst, b))
            else:
                tot["inter_bytes"] += b
                tot["inter_messages"] += 1
                inter.append((src // per_island, dst // per_island, b))
        tot["messages"] += len(rnd)
        t_intra = price_round(cn, intra, topo["intra"])
        t_inter = price_round(num_islands, inter, topo["inter"])
        round_times.append(max(t_intra, t_inter))
    return round_times, tot


def class_volume(rounds, per_island):
    """Port of comm/analysis.rs::class_volume: (intra, inter) messages."""
    intra = inter = 0
    for rnd in rounds:
        for (s, d) in rnd:
            if s // per_island == d // per_island:
                intra += 1
            else:
                inter += 1
    return intra, inter


# --------------------------------------------------------------------------
# Fault injection (fault/plan.rs, net/sim.rs::retransmit_time)
# --------------------------------------------------------------------------


def retransmit_time(topo, src, dst, nbytes):
    """Port of net/sim.rs::retransmit_time: one point-to-point re-send,
    priced as per-message latency plus serialization over a single link of
    the pair's class. ``topo=None`` is the uniform (flat DGX-2) topology."""
    if topo is None or src // topo["per_island"] == dst // topo["per_island"]:
        cls = DGX2 if topo is None else topo["intra"]
    else:
        cls = topo["inter"]
    return cls["latency"] + nbytes / cls["link_bw"]


def fault_plan_generate(seed, count, levels, rounds, ranks):
    """Port of fault/plan.rs::FaultPlan::generate: `count` faults addressed
    uniformly over levels x rounds x ranks^2 via SplitMix64, cycling the
    recoverable kinds drop / corrupt / delay."""
    sm = SplitMix64(seed)
    faults = []
    for k in range(count):
        level = sm.next_u64() % max(levels, 1)
        rnd = sm.next_u64() % max(rounds, 1)
        src = sm.next_u64() % max(ranks, 1)
        dst = sm.next_u64() % max(ranks, 1)
        kind = ["drop", "corrupt", "delay"][k % 3]
        f = dict(level=level, round=rnd, src=src, dst=dst, kind=kind,
                 max_fires=0)
        if kind == "delay":
            f["delay_us"] = 25
        else:
            f["repeat"] = 1
        faults.append(f)
    return dict(max_retries=3, backoff_us=10, faults=faults)


def fault_backoff_seconds(plan, attempt):
    """Port of FaultPlan::backoff_seconds: backoff_us * 2^(attempt-1)."""
    return plan["backoff_us"] * 1e-6 * (1 << min(max(attempt - 1, 0), 20))


def fault_plan_json(plan):
    """Port of FaultPlan::to_json (the `--fault-plan` file format)."""
    faults = []
    for f in plan["faults"]:
        j = {"level": f["level"], "round": f["round"], "kind": f["kind"],
             "fires": f["max_fires"]}
        if f["kind"] == "kill":
            j["rank"] = f["src"]
        else:
            j["src"] = f["src"]
            j["dst"] = f["dst"]
        if f["kind"] in ("drop", "corrupt"):
            j["repeat"] = f["repeat"]
        elif f["kind"] == "delay":
            j["delay_us"] = f["delay_us"]
        faults.append(j)
    return {"max_retries": plan["max_retries"],
            "backoff_us": plan["backoff_us"], "faults": faults}


class FaultInjector:
    """Port of fault/plan.rs::FaultInjector::apply_level — the recovery
    accounting for one level's exchange. Tolerated faults return
    (retries, retry_bytes, recovery_time) deltas; exhausted budgets and
    killed ranks raise (the engine's typed-error paths)."""

    def __init__(self, plan):
        self.plan = plan
        self.fired = [0] * len(plan["faults"])

    def specs_matched(self):
        return sum(1 for c in self.fired if c > 0)

    def _try_fire(self, idx):
        prev = self.fired[idx]
        self.fired[idx] = prev + 1
        cap = self.plan["faults"][idx]["max_fires"]
        return cap == 0 or prev < cap

    def apply_level(self, level, rounds, payloads, topo, num_nodes):
        retries = retry_bytes = 0
        recovery = 0.0
        for idx, spec in enumerate(self.plan["faults"]):
            if spec["level"] != level:
                continue
            if spec["kind"] == "kill":
                if spec["src"] < num_nodes and self._try_fire(idx):
                    raise RuntimeError(
                        f"rank {spec['src']} dead at level {level}")
                continue
            if spec["round"] >= len(rounds):
                continue
            rnd = rounds[spec["round"]]
            ti = next((i for i, (s, d) in enumerate(rnd)
                       if s == spec["src"] and d == spec["dst"]), None)
            if ti is None:
                continue
            nbytes = payloads[spec["round"]][ti]
            if nbytes == 0 or not self._try_fire(idx):
                continue
            if spec["kind"] == "delay":
                recovery += spec["delay_us"] * 1e-6
            else:
                if spec["repeat"] > self.plan["max_retries"]:
                    raise RuntimeError(
                        f"{spec['kind']} transfer {spec['src']}->"
                        f"{spec['dst']} past the retry budget")
                for attempt in range(1, spec["repeat"] + 1):
                    retries += 1
                    retry_bytes += nbytes
                    recovery += (fault_backoff_seconds(self.plan, attempt)
                                 + retransmit_time(topo, spec["src"],
                                                   spec["dst"], nbytes))
        return retries, retry_bytes, recovery


# --------------------------------------------------------------------------
# Payload pricing (bfs/msbfs.rs)
# --------------------------------------------------------------------------


def mask_delta_bytes(entries, distinct_vertices, distinct_masks, active_lanes, nv,
                     words, active_words, entry_words, vertex_words, group_words):
    """Port of msbfs.rs::mask_delta_bytes (word-sparse wide forms).

    For words > 1 the sparse/grouped masks ship word-sparse (a 1-byte
    word-presence bitmap plus only the nonzero 64-bit words), and the
    dense arm ships one presence bitmap per *active* 64-lane cohort plus
    its nonzero cells; at words == 1 the word byte vanishes and the word
    statistics equal the counts, reproducing the original single-word
    pricing exactly.
    """
    if entries == 0:
        return 0
    wb = 1 if words > 1 else 0
    presence = -(-nv // 64) * 8
    sparse = entries * (4 + wb) + 8 * entry_words
    grouped = distinct_masks * (4 + wb) + 8 * group_words + entries * 4
    dense = active_words * presence + 8 * vertex_words
    lane_bitmaps = (1 + active_lanes) * presence
    return min(sparse, grouped, dense, lane_bitmaps)


def mask_delta_bytes_dense(vertex_words, active_words, active_lanes, nv):
    if vertex_words == 0:
        return 0
    presence = -(-nv // 64) * 8
    return min(active_words * presence + 8 * vertex_words,
               (1 + active_lanes) * presence)


def nz_words(m, words):
    """Nonzero 64-bit words of mask m at the given width."""
    c = 0
    for w in range(words):
        if (m >> (64 * w)) & MASK64:
            c += 1
    return c


def words_for_lanes(lanes):
    """Port of msbfs.rs::words_for_lanes: {1, 2, 4, 8}."""
    assert 1 <= lanes <= 512
    w = 1
    while w * 64 < lanes:
        w *= 2
    return w


# --------------------------------------------------------------------------
# Mask-kernel work model (bfs/kernels.rs, bfs/lrb.rs,
# coordinator/backend.rs two-stage sweep/probe)
# --------------------------------------------------------------------------

NUM_LRB_BINS = 33  # lrb.rs::NUM_BINS
CHUNK_VERTICES = 64  # backend.rs::CHUNK_VERTICES


def bin_of_degree(d):
    """Port of lrb.rs::bin_of_degree: degrees 0/1 share bin 0, then one
    bin per power-of-two degree class (bit length of d-1)."""
    return 0 if d <= 1 else (d - 1).bit_length()


def chunk_range_mask(wi, lo, hi):
    """Port of backend.rs::chunk_range_mask: the bits of 64-vertex chunk
    ``wi`` whose vertices fall in the owned range [lo, hi)."""
    start = max(wi * CHUNK_VERTICES, lo)
    end = min((wi + 1) * CHUNK_VERTICES, hi)
    if start >= end:
        return 0
    n = end - start
    shift = start - wi * CHUNK_VERTICES
    return MASK64 if n == 64 else ((1 << n) - 1) << shift


class KernelWork:
    """Port of kernels.rs::KernelWork (one level's counters; batch
    totals sum words/dispatches over levels and max the max)."""

    __slots__ = ("words_touched", "words_skipped", "dispatches",
                 "dispatch_max_work")

    def __init__(self):
        self.words_touched = 0
        self.words_skipped = 0
        self.dispatches = 0
        self.dispatch_max_work = 0

    def record_dispatch(self, work):
        self.dispatches += 1
        if work > self.dispatch_max_work:
            self.dispatch_max_work = work


# --------------------------------------------------------------------------
# Batched engine (coordinator/session.rs run_batch, 1D + 2D, W-word lanes)
# --------------------------------------------------------------------------
#
# Masks are python bigints, which represent any lane width exactly; the
# Rust engine's const-generic word count `W` only changes the *pricing*
# (entry bytes `4 + 8W`, dense switchover `⌈8WV/(4+8W)⌉`), which is what
# the `words` plumbing below mirrors.


class NodeState:
    def __init__(self, nv, lo, hi, track_full, words, adj):
        self.lo, self.hi = lo, hi
        self.nv = nv
        self.words = words
        self.adj = adj  # None = full adjacency (1D); list per owned row (2D)
        self.seen = [0] * nv
        self.visit = [0] * nv
        self.next_mask = [0] * nv
        self.q_local = []
        self.q_next = []
        self.delta = []
        self.delta_stamp = [0] * nv
        self.delta_word_stamp = [0] * (nv * words)
        self.delta_distinct = 0
        self.mask_values = set()
        self.active_lanes = 0
        self.word_entries = [0] * words
        self.word_vertices = [0] * words
        self.group_words = 0
        self.word_mask_values = [set() for _ in range(words)]
        self.edges = 0
        # Persistent fully-settled chunk summary (backend.rs bu_done):
        # bit v%64 of word v//64 set once lane coverage of v is complete.
        # Fresh per batch (reset_for_batch zeroes it in Rust).
        self.bu_done = [0] * (-(-nv // CHUNK_VERTICES))
        self.track_full = track_full
        self.visit_full = [0] * nv if track_full else None
        self.dist = None  # lane-major, node 0 only
        self.g = None  # set by run_batch (1D adjacency)

    def owns(self, v):
        return self.lo <= v < self.hi

    def nbrs(self, v):
        """Owned vertex v's neighbors within this node's block."""
        if self.adj is None:
            return self.g.neighbors(v)
        return self.adj[v - self.lo]

    def discover(self, v, mask, level, owned):
        d = mask & ~self.seen[v]
        if d == 0:
            return
        self.seen[v] |= d
        if self.dist is not None:
            m, lane = d, 0
            while m:
                if m & 1:
                    self.dist[lane][v] = level + 1
                m >>= 1
                lane += 1
        self.delta.append((v, d))
        if self.delta_stamp[v] != level + 1:
            self.delta_stamp[v] = level + 1
            self.delta_distinct += 1
        self.active_lanes |= d
        nzw = 0
        base = v * self.words
        for w in range(self.words):
            dw = (d >> (64 * w)) & MASK64
            if dw:
                nzw += 1
                self.word_entries[w] += 1
                self.word_mask_values[w].add(dw)
                if self.delta_word_stamp[base + w] != level + 1:
                    self.delta_word_stamp[base + w] = level + 1
                    self.word_vertices[w] += 1
        if d not in self.mask_values:
            self.mask_values.add(d)
            self.group_words += nzw
        if owned:
            if self.next_mask[v] == 0:
                self.q_next.append(v)
            self.next_mask[v] |= d

    def per_word_bytes(self, dense_only):
        """Cohort-factored price: W independent single-word messages."""
        total = 0
        for w in range(self.words):
            e = self.word_entries[w]
            dv = self.word_vertices[w]
            al = bin((self.active_lanes >> (64 * w)) & MASK64).count("1")
            if dense_only:
                total += mask_delta_bytes_dense(dv, 1 if dv else 0, al, self.nv)
            else:
                dm = min(len(self.word_mask_values[w]), e)
                total += mask_delta_bytes(
                    e, min(dv, e), dm, al, self.nv, 1,
                    1 if e else 0, e, min(dv, e), dm,
                )
        return total

    def priced(self, entries, bottom_up):
        if bottom_up:
            if entries == 0:
                return 0
            whole = mask_delta_bytes_dense(
                sum(self.word_vertices),
                nz_words(self.active_lanes, self.words),
                bin(self.active_lanes).count("1"),
                self.nv,
            )
            if self.words == 1:
                return whole
            return min(whole, self.per_word_bytes(True))
        whole = mask_delta_bytes(
            entries,
            min(self.delta_distinct, entries),
            min(len(self.mask_values), entries),
            bin(self.active_lanes).count("1"),
            self.nv,
            self.words,
            nz_words(self.active_lanes, self.words),
            sum(self.word_entries),
            sum(self.word_vertices),
            self.group_words,
        )
        if self.words == 1 or entries == 0:
            return whole
        return min(whole, self.per_word_bytes(False))

    def swap_level(self):
        if self.track_full:
            self.visit_full = [0] * self.nv
            for (v, m) in self.delta:
                self.visit_full[v] |= m
        self.q_local = self.q_next
        self.q_next = []
        for v in self.q_local:
            self.visit[v] = self.next_mask[v]
            self.next_mask[v] = 0
        self.delta = []
        self.delta_distinct = 0
        self.mask_values = set()
        self.active_lanes = 0
        self.word_entries = [0] * self.words
        self.word_vertices = [0] * self.words
        self.group_words = 0
        self.word_mask_values = [set() for _ in range(self.words)]
        self.edges = 0


def run_batch(g, nodes, fanout, roots, direction, alpha=15, beta=18,
              mode="1d", grid=None, width_words=1, topo=None,
              kernel="auto", use_lrb=True):
    """direction in {'topdown', 'bottomup', 'diropt'}; mode '1d', '2d'
    (with ``grid = (rows, cols)``), or 'hier' (1D slabs exchanged over the
    grid-of-islands schedule, ``grid = (islands, per_island)``);
    ``width_words`` is the configured BatchWidth floor; ``topo`` switches
    Phase-2 pricing to the two-class clustered simulator (``None`` keeps
    the flat DGX2 pricing bit-for-bit); ``kernel`` in {'auto', 'scalar',
    'chunked'} selects the mask-kernel shape ('auto' resolves to
    'chunked', mirroring KernelVariant::resolved) and ``use_lrb`` the
    degree-binned probe dispatch — both change only the deterministic
    work counters, never a distance or a byte. Returns a metrics dict."""
    ranges, adjs = node_layout(g, nodes, "2d" if mode == "2d" else "1d", grid)
    if mode == "1d":
        rounds = butterfly_schedule(nodes, fanout)
        cols = 1
    elif mode == "hier":
        islands, per_island = grid
        assert islands * per_island == nodes
        rounds = hierarchical_schedule(islands, per_island, fanout)
        cols = 1
    else:
        rows, cols = grid
        rounds = fold_expand_schedule(rows, cols)
    b = len(roots)
    words = max(width_words, words_for_lanes(b))
    full = (1 << b) - 1
    track = direction != "topdown"
    sts = [
        NodeState(g.n, ranges[i][0], ranges[i][1], track, words, adjs[i])
        for i in range(nodes)
    ]
    for st in sts:
        st.g = g
    sts[0].dist = [[INF] * g.n for _ in range(b)]
    for st in sts:
        for lane, r in enumerate(roots):
            bit = 1 << lane
            st.seen[r] |= bit
            if st.dist is not None:
                st.dist[lane][r] = 0
            if track:
                st.visit_full[r] |= bit
            if st.owns(r):
                if st.visit[r] == 0:
                    st.q_local.append(r)
                st.visit[r] |= bit
    dense_threshold = max(-(-(g.n * 8 * words) // (4 + 8 * words)), 1)
    chunked_kernel = kernel != "scalar"  # auto resolves to chunked
    occ_words = -(-g.n // 64)
    levels = []
    sync_rounds = 0
    bottom_up = False
    prev_frontier = 0
    m_unexplored = g.num_edges()
    level = 0
    while True:
        # Distinct frontier vertices: in 2D every node of a processor row
        # queues the row's vertices, so count column-0 representatives.
        frontier = sum(len(st.q_local) for st in sts[::cols])
        if frontier == 0:
            break
        if direction == "bottomup":
            bottom_up = True
        elif direction == "diropt":
            # Edge mass over ALL nodes: row-mates' block degrees sum to
            # each frontier vertex's full degree.
            m_frontier = sum(
                len(st.nbrs(v)) for st in sts for v in st.q_local
            )
            growing = frontier > prev_frontier
            if (not bottom_up and alpha > 0 and growing
                    and m_frontier > m_unexplored // alpha):
                bottom_up = True
            elif (bottom_up and beta > 0 and not growing
                    and frontier < g.n // beta):
                bottom_up = False
            prev_frontier = frontier
        # Phase 1 (two-stage sweep/probe mirror of
        # backend.rs::expand_bottom_up_batch — same probes, same
        # discoveries, plus the per-kernel work counters).
        lw = KernelWork()
        if bottom_up:
            for st in sts:
                st.edges = 0
                # Stage 1: the sweep. Scalar reads W words per owned
                # vertex; chunked reads one bu_done summary word per
                # chunk and skips settled vertices without touching
                # their mask words.
                cand = []
                if chunked_kernel:
                    for wi in range(st.lo // 64, -(-st.hi // 64)):
                        rmask = chunk_range_mask(wi, st.lo, st.hi)
                        lw.words_touched += 1
                        settled = st.bu_done[wi] & rmask
                        lw.words_skipped += words * bin(settled).count("1")
                        bits = ~st.bu_done[wi] & rmask
                        while bits:
                            low = bits & -bits
                            v = wi * 64 + low.bit_length() - 1
                            bits ^= low
                            lw.words_touched += words
                            missing = full & ~st.seen[v]
                            if missing == 0:
                                st.bu_done[wi] |= low
                            else:
                                cand.append((v, missing))
                else:
                    for v in range(st.lo, st.hi):
                        lw.words_touched += words
                        missing = full & ~st.seen[v]
                        if missing:
                            cand.append((v, missing))
                # Stage 2: the probe (pure per candidate, so dispatch
                # order never moves a counter; results are emitted in
                # ascending candidate order either way).
                found = []
                if use_lrb and cand:
                    bin_work = [0] * NUM_LRB_BINS
                    seen_bin = [False] * NUM_LRB_BINS
                for (v, missing) in cand:
                    acc = 0
                    probes = 0
                    for u in st.nbrs(v):
                        probes += 1
                        acc |= st.visit_full[u]
                        if acc & missing == missing:
                            break
                    st.edges += probes
                    d = acc & missing
                    if d:
                        found.append((v, d))
                    if use_lrb:
                        bi = bin_of_degree(len(st.nbrs(v)))
                        seen_bin[bi] = True
                        bin_work[bi] += words * (1 + probes)
                if cand:
                    if use_lrb:
                        for bi in range(NUM_LRB_BINS):
                            if seen_bin[bi]:
                                lw.record_dispatch(bin_work[bi])
                    else:
                        lw.record_dispatch(
                            words * len(cand) + words * st.edges)
                for (v, d) in found:
                    st.discover(v, d, level, True)
        else:
            for st in sts:
                q = st.q_local
                for v in q:
                    mv = st.visit[v]
                    st.visit[v] = 0
                    ns = st.nbrs(v)
                    st.edges += len(ns)
                    for u in ns:
                        st.discover(u, mv, level, st.owns(u))
                # session.rs run_batch_w: each nonempty node reads W
                # mask words per frontier vertex, one dispatch covering
                # its adjacency work.
                if q:
                    lw.words_touched += words * len(q)
                    lw.record_dispatch(st.edges)
        edges = sum(st.edges for st in sts)
        max_node_edges = max(st.edges for st in sts) if sts else 0
        sim_compute = level_time(max_node_edges, bottom_up)
        # Phase 2: pricing is direction-aware (dense wire forms for
        # bottom-up), merge dispatch stays on the entry-count threshold.
        payloads = []
        mask_snap = [None] * nodes
        mask_done = [0] * nodes
        occ_count = [0] * nodes  # popcount of the sender occupancy bitmap
        for rnd in rounds:
            snap = [(len(st.delta), st.priced(len(st.delta), bottom_up))
                    for st in sts]
            for k, st in enumerate(sts):
                if snap[k][0] >= dense_threshold:
                    if mask_snap[k] is None:
                        mask_snap[k] = [0] * g.n
                    for (v, m) in st.delta[mask_done[k]:snap[k][0]]:
                        if mask_snap[k][v] == 0:
                            occ_count[k] += 1
                        mask_snap[k][v] |= m
                    mask_done[k] = snap[k][0]
            payloads.append([snap[src][1] for (src, _) in rnd])
            for (src, dst) in rnd:
                take = snap[src][0]
                # Merge-side word traffic (session.rs batch_phase2): a
                # scalar dense merge reads all W*V snapshot words; a
                # chunked one reads the occupancy bitmap plus W words
                # per occupied vertex; sparse replays W words per entry.
                if take >= dense_threshold:
                    if chunked_kernel:
                        lw.words_touched += occ_words + words * occ_count[src]
                        lw.words_skipped += words * (g.n - occ_count[src])
                    else:
                        lw.words_touched += words * g.n
                    for v, m in enumerate(mask_snap[src]):
                        if m:
                            sts[dst].discover(v, m, level, sts[dst].owns(v))
                else:
                    lw.words_touched += words * take
                    prefix = sts[src].delta[:take]
                    for (v, m) in prefix:
                        sts[dst].discover(v, m, level, sts[dst].owns(v))
        if topo is None:
            round_times, rbytes, rmsgs = simulate_schedule(rounds, payloads, nodes)
            cls = None
        else:
            round_times, cls = simulate_topology(rounds, payloads, nodes, topo)
            rbytes, rmsgs = cls["bytes"], cls["messages"]
        discovered = sum(bin(m).count("1") for (_, m) in sts[0].delta)
        lvl = dict(
            level=level,
            frontier=frontier,
            edges=edges,
            max_node_edges=max_node_edges,
            discovered=discovered,
            messages=rmsgs,
            bytes=rbytes,
            direction="bottomup" if bottom_up else "topdown",
            sim_compute=sim_compute,
            sim_comm=sum(round_times),
            words_touched=lw.words_touched,
            words_skipped=lw.words_skipped,
            dispatches=lw.dispatches,
            dispatch_max_work=lw.dispatch_max_work,
            # Per-(round, transfer) priced bytes — what the fault injector
            # addresses (fault/plan.rs::apply_level sees the same shape).
            payloads=payloads,
        )
        if cls is not None:
            lvl.update(intra_messages=cls["intra_messages"],
                       intra_bytes=cls["intra_bytes"],
                       inter_messages=cls["inter_messages"],
                       inter_bytes=cls["inter_bytes"])
        levels.append(lvl)
        sync_rounds += len(rounds)
        if direction == "diropt":
            next_edges = sum(len(st.nbrs(v)) for st in sts for v in st.q_next)
            m_unexplored = max(m_unexplored - next_edges, 0)
        for st in sts:
            st.swap_level()
        level += 1
    reached_pairs = sum(
        1 for lane in range(b) for d in sts[0].dist[lane] if d != INF
    )
    return dict(
        levels=levels,
        sync_rounds=sync_rounds,
        reached_pairs=reached_pairs,
        dist=sts[0].dist,
        graph_edges=g.num_edges(),
        lane_words=words,
    )


def serial_bfs(g, root):
    dist = [INF] * g.n
    dist[root] = 0
    q, d = [root], 0
    while q:
        nq = []
        for v in q:
            for u in g.neighbors(v):
                if dist[u] == INF:
                    dist[u] = d + 1
                    nq.append(u)
        q = nq
        d += 1
    return dist


# --------------------------------------------------------------------------
# Web-like generator (graph/gen/weblike.rs) — the storage-section graph
# --------------------------------------------------------------------------


def weblike(n, edge_factor, seed, copy_prob=0.25, tail_len=0, window=0,
            strand_frac=0.0, strand_len=0):
    """Port of graph/gen/weblike.rs::weblike (RNG call order preserved)."""
    assert n >= 2
    strand_total = int(n * strand_frac)
    n_core = max(n - strand_total, 2)
    total = n + tail_len
    rng = Xoshiro256StarStar(seed)
    raw = [(0, 1)]
    endpoints = [0, 1]
    for v in range(2, n_core):
        for _ in range(edge_factor):
            lo = (len(endpoints) - window
                  if window > 0 and len(endpoints) > window else 0)
            t = endpoints[lo + rng.next_below(len(endpoints) - lo)]
            if rng.next_f64() < copy_prob:
                wlo = v - window if window > 0 and v > window else 0
                t = wlo + rng.next_below(v - wlo)
            raw.append((v, t))
            endpoints.append(v)
            endpoints.append(t)
    if strand_total > 0:
        slen = max(strand_len, 1)
        next_id = n_core
        end = n_core + strand_total
        while next_id < end:
            prev = rng.next_below(n_core)
            for _ in range(slen):
                if next_id >= end:
                    break
                raw.append((prev, next_id))
                prev = next_id
                next_id += 1
    prev = 0
    for i in range(tail_len):
        t = n + i
        raw.append((prev, t))
        prev = t
    return build_undirected(total, raw)


# --------------------------------------------------------------------------
# Degree-sort relabeling (partition/relabel.rs)
# --------------------------------------------------------------------------


def degree_sort_relabeling(g):
    """Returns (new_id, old_id); stable descending-degree order."""
    order = sorted(range(g.n), key=lambda v: -g.degree(v))
    new_id = [0] * g.n
    for new, old in enumerate(order):
        new_id[old] = new
    return new_id, order


def apply_relabeling(g, new_id):
    arcs = []
    for u in range(g.n):
        nu = new_id[u]
        for v in g.neighbors(u):
            arcs.append((nu, new_id[v]))
    arcs.sort()
    return Csr(g.n, arcs)


# --------------------------------------------------------------------------
# .bbfs v2 store codec (graph/store/{varint,writer,loader}.rs)
# --------------------------------------------------------------------------
#
# The encoder is a byte-for-byte mirror of the Rust writer: the committed
# `storage` section's sizes and fingerprint only cross-validate the two
# implementations if both produce the identical container image.

V2_MAGIC = b"BBFSCSR2"
HEADER_LEN = 72
DATA_ALIGN = 4096
BLOCK_SIZE_DEFAULT = 1024
MAX_VARINT_LEN = 10
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3


def encode_varint(value, out):
    while True:
        byte = value & 0x7F
        value >>= 7
        if value == 0:
            out.append(byte)
            return
        out.append(byte | 0x80)


def decode_varint(buf, pos):
    value, shift = 0, 0
    for i in range(MAX_VARINT_LEN):
        byte = buf[pos + i]
        group = byte & 0x7F
        assert shift < 64 and not (shift == 63 and group > 1), "varint overflow"
        value |= group << shift
        if not byte & 0x80:
            return value, pos + i + 1
        shift += 7
    raise AssertionError("varint longer than 10 bytes")


def v1_snapshot_bytes(g):
    """Size of the raw-CSR v1 snapshot (store/writer.rs)."""
    return 24 + 8 * (g.n + 1) + 4 * g.num_edges()


def encode_store(g, relabel=False, block_size=BLOCK_SIZE_DEFAULT):
    """Port of store/writer.rs::encode_store. Returns (image, old_id)."""
    if relabel:
        new_id, old_id = degree_sort_relabeling(g)
        graph = apply_relabeling(g, new_id)
    else:
        old_id = None
        graph = g
    n, m = graph.n, graph.num_edges()
    bs = block_size
    num_blocks = -(-n // bs)
    data = bytearray()
    index = []
    for b in range(num_blocks):
        index.append((len(data), graph.offsets[b * bs]))
        lo, hi = b * bs, min((b + 1) * bs, n)
        for v in range(lo, hi):
            encode_varint(graph.degree(v), data)
        for v in range(lo, hi):
            prev = None
            for w in graph.neighbors(v):
                if prev is not None:
                    assert w >= prev, "unsorted adjacency"
                encode_varint(w if prev is None else w - prev, data)
                prev = w
    index.append((len(data), m))
    flags = 1 if relabel else 0
    index_len = 16 * (num_blocks + 1)
    perm_len = 4 * n if relabel else 0
    perm_off = HEADER_LEN + index_len if relabel else 0
    data_off = -(-(HEADER_LEN + index_len + perm_len) // DATA_ALIGN) * DATA_ALIGN
    file_len = data_off + len(data)
    out = bytearray()
    out += V2_MAGIC
    out += (2).to_bytes(4, "little")
    out += flags.to_bytes(4, "little")
    out += n.to_bytes(8, "little")
    out += m.to_bytes(8, "little")
    out += bs.to_bytes(4, "little")
    out += num_blocks.to_bytes(4, "little")
    out += HEADER_LEN.to_bytes(8, "little")
    out += perm_off.to_bytes(8, "little")
    out += data_off.to_bytes(8, "little")
    out += file_len.to_bytes(8, "little")
    for start, first_edge in index:
        out += start.to_bytes(8, "little")
        out += first_edge.to_bytes(8, "little")
    if relabel:
        for old in old_id:
            out += old.to_bytes(4, "little")
    out += bytes(data_off - len(out))
    out += data
    assert len(out) == file_len
    return bytes(out), old_id


def decode_store(image):
    """Happy-path port of store/loader.rs: image -> (Csr, old_id|None).

    Mirrors the structural checks (spans, id bounds, degree sums); the
    Rust corpus tests own the full hostile-input error taxonomy.
    """
    assert image[0:8] == V2_MAGIC, "bad magic"
    assert int.from_bytes(image[8:12], "little") == 2, "bad version"
    flags = int.from_bytes(image[12:16], "little")
    n = int.from_bytes(image[16:24], "little")
    m = int.from_bytes(image[24:32], "little")
    bs = int.from_bytes(image[32:36], "little")
    num_blocks = int.from_bytes(image[36:40], "little")
    data_off = int.from_bytes(image[56:64], "little")
    assert int.from_bytes(image[64:72], "little") == len(image), "file_len"
    index = []
    for b in range(num_blocks + 1):
        at = HEADER_LEN + 16 * b
        index.append((int.from_bytes(image[at:at + 8], "little"),
                      int.from_bytes(image[at + 8:at + 16], "little")))
    old_id = None
    if flags & 1:
        at = HEADER_LEN + 16 * (num_blocks + 1)
        old_id = [int.from_bytes(image[at + 4 * i:at + 4 * i + 4], "little")
                  for i in range(n)]
    offsets = [0]
    edges = []
    for b in range(num_blocks):
        lo, hi = b * bs, min((b + 1) * bs, n)
        buf = image[data_off + index[b][0]:data_off + index[b + 1][0]]
        pos = 0
        degrees = []
        for _ in range(lo, hi):
            d, pos = decode_varint(buf, pos)
            degrees.append(d)
        assert sum(degrees) == index[b + 1][1] - index[b][1], "degree sum"
        for d in degrees:
            prev = 0
            for k in range(d):
                raw, pos = decode_varint(buf, pos)
                w = raw if k == 0 else prev + raw
                assert w < n, "neighbor out of range"
                prev = w
                edges.append(w)
            offsets.append(len(edges))
        assert pos == len(buf), "trailing bytes"
    assert len(edges) == m, "edge count"
    csr = Csr(0, [])
    csr.n, csr.offsets, csr.edges = n, offsets, edges
    return csr, old_id


def store_fingerprint(image):
    """FNV-1a 64 over header + index + permutation bytes (loader.rs)."""
    flags = int.from_bytes(image[12:16], "little")
    n = int.from_bytes(image[16:24], "little")
    num_blocks = int.from_bytes(image[36:40], "little")
    end = HEADER_LEN + 16 * (num_blocks + 1) + (4 * n if flags & 1 else 0)
    h = FNV_OFFSET
    for b in image[:end]:
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def materialize_counters(prefix, cuts, n, bs):
    """Decode-counter deltas of materializing 1D row slabs.

    Mirrors loader.rs::decode_rows_filtered per part (lo, hi): every
    overlapped block pays one block fetch and a full degree pass, and
    adjacency decoding runs from the block start (sequential varints
    cannot be skipped) up to min(block end, hi) — so the edge counter
    includes rows below `lo` in the first block.
    """
    deg = edges = blocks = 0
    for i in range(len(cuts) - 1):
        lo, hi = cuts[i], cuts[i + 1]
        first, last = lo // bs, max(-(-hi // bs), lo // bs)
        blocks += last - first
        for b in range(first, last):
            blo, bhi = b * bs, min((b + 1) * bs, n)
            deg += bhi - blo
            edges += prefix[min(bhi, hi)] - prefix[blo]
    return deg, edges, blocks


# --------------------------------------------------------------------------
# The protocol (harness/protocol.rs)
# --------------------------------------------------------------------------

PROTOCOL = dict(
    name="engine-bench-v7",
    graph="kron-like",
    kron_scale=21,
    kron_edge_factor=16,
    kron_seed=0xB0B0_0007,
    scale_delta=-10,
    batch_width=64,
    root_seed=7,
    node_counts=[16, 64],
    fanout=4,
    # Width ablation (v2): wide lane masks vs chunked 64-root execution.
    wide_widths=[64, 256],
    wide_nodes=16,
    wide_grid=(4, 4),
    chunk=64,
    # Serve throughput (v3): open-loop coalescing sim at a fixed load
    # point — 256 requests 30 us apart against a single simulated worker,
    # baseline (window 0, batch 1) vs coalesced (window 240 us, batch 64).
    serve_requests=256,
    serve_gap_us=30,
    serve_queue_depth=64,
    serve_window_us=240,
    serve_max_batch=64,
    serve_seed=11,
    # Storage (v4): `.bbfs` v2 container of the web-like suite graph —
    # compression sizes, container fingerprint, warm-start decode
    # counters. The weblike parameters are the suite's "web-like" row
    # (GAP_web analog) at scale delta -8.
    storage_graph="web-like",
    storage_scale=20,
    storage_scale_delta=-8,
    storage_edge_factor=38,
    storage_strand_permille=180,
    storage_strand_len=9,
    storage_seed=0xB0B0_0006,
    storage_nodes=16,
    # Hierarchical (v5): flat 1D vs 2D fold/expand vs grid-of-islands at
    # p = 64, all priced under the same heterogeneous `dgx2-cluster`
    # topology (8 islands of 8, 10:1 intra:inter bandwidth).
    hier_nodes=64,
    hier_grid=(8, 8),
    # Fault recovery (v6): a committed seeded fault schedule against the
    # 16-node 1D diropt batch; seed 43 fires all three recoverable kinds
    # (drop, corrupt, delay) against live transfers; acceptance requires
    # retries >= 1 and bit-identical distances under recovery.
    fault_seed=43,
    fault_count=6,
    fault_levels=4,
    fault_rounds=2,
    fault_nodes=16,
    # Kernel ablation (v7): scalar vs chunked mask kernels (and LRB off)
    # per partition mode, forced bottom-up at 16 nodes — the committed
    # deterministic work counters behind the SIMD-shaped kernel claims.
    kernel_widths=[64, 256, 512],
    kernel_hier_grid=(4, 4),
)


def gteps(edges, seconds):
    return float("inf") if seconds <= 0 else edges / seconds / 1e9


def direction_report(m):
    depth = len(m["levels"])
    bu_levels = sum(1 for l in m["levels"] if l["direction"] == "bottomup")
    total_edges = sum(l["edges"] for l in m["levels"])
    bu_edges = sum(l["edges"] for l in m["levels"] if l["direction"] == "bottomup")
    total_bytes = sum(l["bytes"] for l in m["levels"])
    sim_seconds = sum(l["sim_compute"] + l["sim_comm"] for l in m["levels"])
    return {
        "levels": depth,
        "bottom_up_levels": bu_levels,
        "edges_inspected": total_edges,
        "bottom_up_edges": bu_edges,
        "bytes": total_bytes,
        "bytes_per_level": total_bytes / max(depth, 1),
        "messages": sum(l["messages"] for l in m["levels"]),
        "sync_rounds": m["sync_rounds"],
        "reached_pairs": m["reached_pairs"],
        "sim_seconds": sim_seconds,
        "sim_gteps": gteps(m["graph_edges"], sim_seconds),
        "per_level": [
            {
                "level": l["level"],
                "frontier": l["frontier"],
                "edges": l["edges"],
                "bytes": l["bytes"],
                "direction": l["direction"],
            }
            for l in m["levels"]
        ],
    }


def batch_totals(m):
    """Width-ablation totals of one run_batch metrics dict."""
    return dict(
        levels=len(m["levels"]),
        sync_rounds=m["sync_rounds"],
        messages=sum(l["messages"] for l in m["levels"]),
        bytes=sum(l["bytes"] for l in m["levels"]),
        edges_inspected=sum(l["edges"] for l in m["levels"]),
        reached_pairs=m["reached_pairs"],
        sim_seconds=sum(l["sim_compute"] + l["sim_comm"] for l in m["levels"]),
    )


def width_ablation(g):
    """Port of harness/protocol.rs::width_ablation_json."""
    entries = []
    for mode_2d in [False, True]:
        for width in PROTOCOL["wide_widths"]:
            roots = sample_batch_roots(g, width, PROTOCOL["root_seed"])
            words = words_for_lanes(width)
            kw = (dict(mode="2d", grid=PROTOCOL["wide_grid"])
                  if mode_2d else dict())
            m = run_batch(g, PROTOCOL["wide_nodes"], PROTOCOL["fanout"],
                          roots, "topdown", width_words=words, **kw)
            chunked = dict(chunks=0, sync_rounds=0, messages=0, bytes=0,
                           reached_pairs=0, sim_seconds=0.0)
            for k in range(0, width, PROTOCOL["chunk"]):
                cm = run_batch(g, PROTOCOL["wide_nodes"], PROTOCOL["fanout"],
                               roots[k:k + PROTOCOL["chunk"]], "topdown",
                               width_words=1, **kw)
                ct = batch_totals(cm)
                chunked["chunks"] += 1
                for key in ["sync_rounds", "messages", "bytes",
                            "reached_pairs", "sim_seconds"]:
                    chunked[key] += ct[key]
            entry = {
                "mode": "2d" if mode_2d else "1d",
                "width": width,
                "nodes": PROTOCOL["wide_nodes"],
                "direction": "topdown",
                "lane_words": m["lane_words"],
                "entry_bytes": 4 + 8 * m["lane_words"],
                "chunked": chunked,
            }
            if mode_2d:
                entry["grid"] = "%dx%d" % PROTOCOL["wide_grid"]
            entry.update(batch_totals(m))
            entries.append(entry)
    return entries


def kernel_work_totals(m):
    """Port of harness/protocol.rs::kernel_work_json: one variant's
    batch-total counters (words and dispatches sum over levels, the max
    dispatch is a max; tail_words is the last level's word traffic)."""
    ls = m["levels"]
    return {
        "words_touched": sum(l["words_touched"] for l in ls),
        "words_skipped": sum(l["words_skipped"] for l in ls),
        "dispatches": sum(l["dispatches"] for l in ls),
        "dispatch_max_work": max((l["dispatch_max_work"] for l in ls),
                                 default=0),
        "tail_words": ls[-1]["words_touched"] if ls else 0,
    }


def kernel_ablation(g):
    """Port of harness/protocol.rs::kernel_ablation_json. Roots come
    from a single connected component (the reachable set of the protocol
    seed root, cycled in ascending vertex order) so every lane
    saturates and the chunked kernel's settled-skip has real work to
    elide on the tail levels."""
    p = PROTOCOL
    seed_root = sample_batch_roots(g, 1, p["root_seed"])[0]
    sd = serial_bfs(g, seed_root)
    comp = [v for v in range(g.n) if sd[v] != INF]
    entries = []
    for mode in ["1d", "2d", "hier"]:
        if mode == "2d":
            kw = dict(mode="2d", grid=p["wide_grid"])
        elif mode == "hier":
            kw = dict(mode="hier", grid=p["kernel_hier_grid"],
                      topo=dgx2_cluster_topo(p["kernel_hier_grid"][1]))
        else:
            kw = dict()
        for width in p["kernel_widths"]:
            roots = [comp[i % len(comp)] for i in range(width)]
            words = words_for_lanes(width)

            def run(kernel, use_lrb):
                return run_batch(g, p["wide_nodes"], p["fanout"], roots,
                                 "bottomup", width_words=words,
                                 kernel=kernel, use_lrb=use_lrb, **kw)

            scalar = run("scalar", True)
            chunked = run("chunked", True)
            no_lrb = run("chunked", False)
            equal = (scalar["dist"] == chunked["dist"]
                     and chunked["dist"] == no_lrb["dist"])
            entry = {
                "mode": mode,
                "width": width,
                "nodes": p["wide_nodes"],
            }
            if mode == "2d":
                entry["grid"] = "%dx%d" % p["wide_grid"]
            if mode == "hier":
                entry["islands"] = "%dx%d" % p["kernel_hier_grid"]
            entry.update(
                direction="bottomup",
                lane_words=chunked["lane_words"],
                levels=len(chunked["levels"]),
                reached_pairs=chunked["reached_pairs"],
                edges_inspected=sum(l["edges"] for l in chunked["levels"]),
                distances_equal=equal,
                scalar=kernel_work_totals(scalar),
                chunked=kernel_work_totals(chunked),
                no_lrb=kernel_work_totals(no_lrb),
            )
            entries.append(entry)
    return entries


# --------------------------------------------------------------------------
# Serve-mode coalescer + throughput sim (serve/coalescer.rs, serve/metrics.rs,
# harness/protocol.rs::serve_sim_mode)
# --------------------------------------------------------------------------


class Coalescer:
    """Port of rust/src/serve/coalescer.rs::Coalescer.

    Bounded FIFO admission queue with window/batch-full dispatch over an
    abstract microsecond clock: a batch is due when it is full (at the
    arrival time of the request that filled it) or when the oldest
    request's window expires, whichever comes first; ``take_batch``
    drains oldest-first; past ``depth`` queued requests admission is
    refused (the server answers a typed Overloaded). Pending entries are
    ``(arrived_us, deadline_us_or_None, item)`` tuples.
    """

    def __init__(self, window_us, max_batch, depth):
        assert max_batch >= 1, "max_batch must be at least 1"
        assert depth >= 1, "queue depth must be at least 1"
        self.window_us = window_us
        self.max_batch = max_batch
        self.depth = depth
        self.pending = []

    def __len__(self):
        return len(self.pending)

    def try_push(self, now_us, deadline_us, item):
        """Admit a request; False when the queue is at capacity."""
        if len(self.pending) >= self.depth:
            return False
        self.pending.append((now_us, deadline_us, item))
        return True

    def due_at(self):
        """Instant the oldest batch becomes due, None when empty.

        Batch-full beats window expiry: with ``max_batch`` requests
        queued the batch was due the moment the filling one arrived.
        """
        if len(self.pending) >= self.max_batch:
            return self.pending[self.max_batch - 1][0]
        if not self.pending:
            return None
        return self.pending[0][0] + self.window_us

    def due(self, now_us):
        t = self.due_at()
        return t is not None and t <= now_us

    def take_batch(self):
        """Drain the oldest ``min(len, max_batch)`` requests, FIFO."""
        n = min(len(self.pending), self.max_batch)
        batch, self.pending = self.pending[:n], self.pending[n:]
        return batch

    def expire(self, now_us):
        """Remove every request past its deadline, preserving order."""
        expired = [p for p in self.pending
                   if p[1] is not None and now_us >= p[1]]
        self.pending = [p for p in self.pending
                        if p[1] is None or now_us < p[1]]
        return expired


def nearest_rank_us(sorted_us, p):
    """Port of rust/src/serve/metrics.rs::nearest_rank_us."""
    n = len(sorted_us)
    if n == 0:
        return 0
    rank = min(max(math.ceil(p / 100.0 * n), 1), n)
    return sorted_us[rank - 1]


def serve_sim_mode(g, window_us, max_batch, service_cache=None):
    """Port of harness/protocol.rs::serve_sim_mode.

    Discrete-event loop: request i arrives at ``i * serve_gap_us``; a
    batch starts at ``max(due_at, worker_free)`` with arrivals at or
    before that instant admitted first; service time is the real
    engine's simulated clock for that root multiset quantized up to
    integer microseconds (``ceil(sim_seconds * 1e6)``), so every latency
    is an integer and the Rust checker compares them exactly.
    """
    if service_cache is None:
        service_cache = {}

    def service_us(batch_roots):
        key = tuple(batch_roots)
        if key not in service_cache:
            m = run_batch(g, PROTOCOL["wide_nodes"], PROTOCOL["fanout"],
                          list(batch_roots), "topdown", width_words=1)
            service_cache[key] = int(
                math.ceil(batch_totals(m)["sim_seconds"] * 1e6))
        return service_cache[key]

    roots = sample_batch_roots(g, PROTOCOL["serve_requests"],
                               PROTOCOL["serve_seed"])
    c = Coalescer(window_us, max_batch, PROTOCOL["serve_queue_depth"])
    latencies, widths = [], []
    rejected, worker_free, last_finish = 0, 0, 0
    nxt = 0
    while True:
        t_arr = nxt * PROTOCOL["serve_gap_us"] if nxt < len(roots) else None
        t_disp = c.due_at()
        if t_disp is not None:
            t_disp = max(t_disp, worker_free)
        if t_arr is None and t_disp is None:
            break
        # Ties admit the arrival first (mirrors the Rust `ta <= t`).
        arrival_first = t_disp is None or (
            t_arr is not None and t_arr <= t_disp)
        if arrival_first:
            if not c.try_push(t_arr, None, roots[nxt]):
                rejected += 1
            nxt += 1
        else:
            batch = c.take_batch()
            finish = t_disp + service_us([p[2] for p in batch])
            worker_free = last_finish = finish
            widths.append(len(batch))
            for arrived, _deadline, _item in batch:
                latencies.append(finish - arrived)
    completed = len(latencies)
    s = sorted(latencies)
    mean_latency = sum(latencies) / completed if completed else 0.0
    qps = completed * 1e6 / last_finish if last_finish else 0.0
    batches = len(widths)
    mean_width = sum(widths) / batches if batches else 0.0
    return {
        "window_us": window_us,
        "max_batch": max_batch,
        "offered": len(roots),
        "completed": completed,
        "rejected": rejected,
        "timed_out": 0,
        "p50_us": nearest_rank_us(s, 50.0),
        "p99_us": nearest_rank_us(s, 99.0),
        "mean_latency_us": mean_latency,
        "qps": qps,
        "batches": batches,
        "mean_width": mean_width,
        "max_width": max(widths) if widths else 0,
        "span_us": last_finish,
    }


def serve_throughput(g):
    """Port of harness/protocol.rs::serve_throughput_json."""
    cache = {}
    return {
        "sim": {
            "requests": PROTOCOL["serve_requests"],
            "arrival_gap_us": PROTOCOL["serve_gap_us"],
            "queue_depth": PROTOCOL["serve_queue_depth"],
            "root_seed": PROTOCOL["serve_seed"],
            "nodes": PROTOCOL["wide_nodes"],
            "fanout": PROTOCOL["fanout"],
            "mode": "1d",
            "direction": "topdown",
            "baseline": serve_sim_mode(g, 0, 1, cache),
            "coalesced": serve_sim_mode(g, PROTOCOL["serve_window_us"],
                                        PROTOCOL["serve_max_batch"], cache),
        }
    }


def hier_mode_report(m):
    """Port of harness/protocol.rs::hier_mode_json: one mode's totals
    with the per-link-class traffic split."""
    ls = m["levels"]
    return {
        "levels": len(ls),
        "sync_rounds": m["sync_rounds"],
        "messages": sum(l["messages"] for l in ls),
        "bytes": sum(l["bytes"] for l in ls),
        "intra_messages": sum(l["intra_messages"] for l in ls),
        "intra_bytes": sum(l["intra_bytes"] for l in ls),
        "inter_messages": sum(l["inter_messages"] for l in ls),
        "inter_bytes": sum(l["inter_bytes"] for l in ls),
        "reached_pairs": m["reached_pairs"],
        "sim_seconds": sum(l["sim_compute"] + l["sim_comm"] for l in ls),
    }


def static_schedule_report(rounds, per_island):
    """Port of harness/protocol.rs::static_schedule_json."""
    intra, inter = class_volume(rounds, per_island)
    return {
        "rounds": len(rounds),
        "messages": sum(len(r) for r in rounds),
        "intra_messages": intra,
        "inter_messages": inter,
    }


def hierarchical_report(g):
    """Port of harness/protocol.rs::hierarchical_json: the three layouts
    at p = 64 under identical dgx2-cluster pricing."""
    p = PROTOCOL
    islands, per_island = p["hier_grid"]
    nodes = p["hier_nodes"]
    roots = sample_batch_roots(g, p["batch_width"], p["root_seed"])
    topo = dgx2_cluster_topo(per_island)
    modes = {}
    for mode in ["1d", "2d", "hier"]:
        grid = None if mode == "1d" else (islands, per_island)
        m = run_batch(g, nodes, p["fanout"], roots, "topdown",
                      mode=mode, grid=grid, topo=topo)
        modes[mode] = hier_mode_report(m)
    s1 = modes["1d"]["sim_seconds"]
    s2 = modes["2d"]["sim_seconds"]
    sh = modes["hier"]["sim_seconds"]
    flat = butterfly_schedule(nodes, p["fanout"])
    hier = hierarchical_schedule(islands, per_island, p["fanout"])
    return {
        "nodes": nodes,
        "islands": f"{islands}x{per_island}",
        "fanout": p["fanout"],
        "width": p["batch_width"],
        "seed": p["root_seed"],
        "net": topo["name"],
        "speed_ratio": topo["intra"]["link_bw"] / topo["inter"]["link_bw"],
        "direction": "topdown",
        "modes": modes,
        "speedup_vs_1d": s1 / sh,
        "speedup_vs_2d": s2 / sh,
        "static_schedule": {
            "flat_1d": static_schedule_report(flat, per_island),
            "hier": static_schedule_report(hier, per_island),
        },
    }


def storage_report():
    """Port of harness/protocol.rs::storage_json.

    Sizes and the fingerprint come from the byte-exact encoder; the
    decode counters are computed analytically from the degree prefix and
    the 1D partition cuts (the same arithmetic the Rust loader's
    counters perform); the distance probes run against the serial BFS
    oracle, which the engine is bit-identical to (selftest).
    """
    p = PROTOCOL
    scale = max(p["storage_scale"] + p["storage_scale_delta"], 4)
    g = weblike(1 << scale, p["storage_edge_factor"], p["storage_seed"],
                strand_frac=p["storage_strand_permille"] / 1000.0,
                strand_len=p["storage_strand_len"])
    n, m = g.n, g.num_edges()
    v1 = v1_snapshot_bytes(g)
    plain, _ = encode_store(g)
    relabeled, old_id = encode_store(g, relabel=True)
    bs = BLOCK_SIZE_DEFAULT
    num_blocks = -(-n // bs)
    root = sample_batch_roots(g, 1, p["root_seed"])[0]
    reference = serial_bfs(g, root)

    # Round-trip both containers and probe distances through each.
    decoded, dperm = decode_store(plain)
    assert dperm is None
    plain_ok = decoded.offsets == g.offsets and decoded.edges == g.edges
    rdecoded, rold = decode_store(relabeled)
    assert rold == old_id
    new_id = [0] * n
    for newv, old in enumerate(rold):
        new_id[old] = newv
    rg = apply_relabeling(g, new_id)
    relabeled_ok = (rdecoded.offsets == rg.offsets
                    and rdecoded.edges == rg.edges)
    cold_dist = serial_bfs(decoded, root)
    warm_dist = serial_bfs(decoded, root)  # same bytes, same graph
    rdist_new = serial_bfs(rdecoded, new_id[root])
    relabeled_dist = [rdist_new[new_id[v]] for v in range(n)]

    # Decode counters: cold 1D build = one degree-only pass (n entries),
    # then materialize decodes each partition slab's blocks; warm start
    # decodes nothing until materialize. Eager = one full decode.
    cuts = balanced_cuts_from_prefix(g.offsets, p["storage_nodes"])
    deg, edec, blocks = materialize_counters(g.offsets, cuts, n, bs)

    def counters(d, e, b):
        return {"degree_entries": d, "edges": e, "blocks": b}

    return {
        "graph": {
            "name": p["storage_graph"],
            "scale_delta": p["storage_scale_delta"],
            "vertices": n,
            "edges": m,
        },
        "nodes": p["storage_nodes"],
        "fanout": p["fanout"],
        "mode": "1d",
        "block_size": bs,
        "v1_bytes": v1,
        "v2_bytes": len(plain),
        "v2_relabeled_bytes": len(relabeled),
        "compression_ratio": v1 / len(plain),
        "relabeled_ratio": v1 / len(relabeled),
        "fingerprint": "%016x" % store_fingerprint(plain),
        "load_counters": {
            "eager": counters(n, m, num_blocks),
            "cold_build": {
                "at_load": counters(n, 0, 0),
                "after_materialize": counters(n + deg, edec, blocks),
            },
            "warm_start": {
                "at_load": counters(0, 0, 0),
                "after_materialize": counters(deg, edec, blocks),
            },
            # 2D cold build: one streaming degree/in-degree pass decodes
            # every block exactly once (stream_degree_prefixes) — the
            # counters at load are exactly {n, m, num_blocks}.
            "two_d_cold": {
                "at_load": counters(n, m, num_blocks),
            },
        },
        "warm_equals_cold": warm_dist == cold_dist,
        "matches_in_memory": (plain_ok and relabeled_ok
                              and cold_dist == reference
                              and relabeled_dist == reference),
    }


def fault_recovery_report(g):
    """Port of harness/protocol.rs::fault_recovery_json: the committed
    seeded schedule injected into the 16-node 1D diropt 64-root batch,
    next to the identical fault-free run. The faulted run re-executes the
    batch with the injector applied at every level's exchange — exactly
    the seam session.rs::check_faults hooks."""
    p = PROTOCOL
    nodes = p["fault_nodes"]
    roots = sample_batch_roots(g, p["batch_width"], p["root_seed"])
    free = run_batch(g, nodes, p["fanout"], roots, "diropt")
    free_sim = sum(l["sim_compute"] + l["sim_comm"] for l in free["levels"])
    free_bytes = sum(l["bytes"] for l in free["levels"])
    plan = fault_plan_generate(p["fault_seed"], p["fault_count"],
                               p["fault_levels"], p["fault_rounds"], nodes)
    inj = FaultInjector(plan)
    rounds = butterfly_schedule(nodes, p["fanout"])
    faulted = run_batch(g, nodes, p["fanout"], roots, "diropt")
    retries = retry_bytes = 0
    recovery = 0.0
    for lvl in faulted["levels"]:
        r, rb, rt = inj.apply_level(lvl["level"], rounds, lvl["payloads"],
                                    None, nodes)
        retries += r
        retry_bytes += rb
        recovery += rt
    equal = faulted["dist"] == free["dist"]
    sim_with_recovery = free_sim + recovery
    return {
        "config": {
            "nodes": nodes,
            "fanout": p["fanout"],
            "mode": "1d",
            "direction": "diropt",
            "width": p["batch_width"],
            "seed": p["root_seed"],
        },
        "plan": fault_plan_json(plan),
        "fault_free": {
            "levels": len(free["levels"]),
            "bytes": free_bytes,
            "sim_seconds": free_sim,
        },
        "faulted": {
            "injected": len(plan["faults"]),
            "matched": inj.specs_matched(),
            "retries": retries,
            "retry_bytes": retry_bytes,
            "recovery_time": recovery,
            "sim_seconds": sim_with_recovery,
        },
        "equal_distances": equal,
        "overhead_ratio": sim_with_recovery / free_sim,
    }


def engine_bench_report():
    scale = max(PROTOCOL["kron_scale"] + PROTOCOL["scale_delta"], 4)
    g = kronecker(scale, PROTOCOL["kron_edge_factor"], PROTOCOL["kron_seed"])
    roots = sample_batch_roots(g, PROTOCOL["batch_width"], PROTOCOL["root_seed"])
    configs = []
    for p in PROTOCOL["node_counts"]:
        dirs = {}
        for d in ["topdown", "bottomup", "diropt"]:
            m = run_batch(g, p, PROTOCOL["fanout"], roots, d)
            dirs[d] = direction_report(m)
        configs.append({
            "nodes": p,
            "fanout": PROTOCOL["fanout"],
            "mode": "1d",
            "directions": dirs,
        })
    return {
        "protocol": PROTOCOL["name"],
        "graph": {
            "name": PROTOCOL["graph"],
            "scale_delta": PROTOCOL["scale_delta"],
            "vertices": g.n,
            "edges": g.num_edges(),
        },
        "batch": {
            "width": PROTOCOL["batch_width"],
            "seed": PROTOCOL["root_seed"],
        },
        "configs": configs,
        "width_ablation": width_ablation(g),
        "serve_throughput": serve_throughput(g),
        "storage": storage_report(),
        "hierarchical": hierarchical_report(g),
        "fault_recovery": fault_recovery_report(g),
        "kernel_ablation": kernel_ablation(g),
    }


# --------------------------------------------------------------------------
# Self-test + CLI
# --------------------------------------------------------------------------


def selftest():
    rng = Xoshiro256StarStar(0x5E1F)
    cases = 0
    for _ in range(60):
        n = 5 + rng.next_below(200)
        ef = 1 + rng.next_below(5)
        g = uniform_random(n, ef, rng.next_u64())
        b = 1 + rng.next_below(16)
        roots = [rng.next_below(n) for _ in range(b)]
        nodes = 1 + rng.next_below(min(8, n))
        fanout = 1 + rng.next_below(4)
        want = [serial_bfs(g, r) for r in roots]
        base = None
        for d in ["topdown", "bottomup", "diropt"]:
            m = run_batch(g, nodes, fanout, roots, d)
            for lane in range(b):
                assert m["dist"][lane] == want[lane], (
                    f"n={n} nodes={nodes} f={fanout} {d} lane {lane}"
                )
            tm = (len(m["levels"]), m["reached_pairs"])
            if base is None:
                base = tm
            else:
                assert tm == base, f"level count diverged under {d}"
            cases += 1
    print(f"selftest: {cases} direction runs bit-identical to serial oracle")
    # Wide lanes × modes: widths crossing every word boundary, 1D and 2D
    # grids, every direction, plus a width_words floor above the minimum
    # (pricing-only — distances must not move).
    wide_cases = 0
    for _ in range(24):
        n = 8 + rng.next_below(120)
        ef = 1 + rng.next_below(4)
        g = uniform_random(n, ef, rng.next_u64())
        b = 1 + rng.next_below(140)
        roots = [rng.next_below(n) for _ in range(b)]
        want = [serial_bfs(g, r) for r in roots]
        if rng.next_below(2) == 0:
            mode, grid = "1d", None
            nodes = 1 + rng.next_below(min(6, n))
        else:
            mode = "2d"
            grid = (1 + rng.next_below(min(3, n)), 1 + rng.next_below(min(3, n)))
            nodes = grid[0] * grid[1]
        d = ["topdown", "bottomup", "diropt"][rng.next_below(3)]
        floor = words_for_lanes(b) * (1 + rng.next_below(2))
        floor = min(floor, 8)
        m = run_batch(g, nodes, 1 + rng.next_below(4), roots, d,
                      mode=mode, grid=grid, width_words=floor)
        for lane in range(b):
            assert m["dist"][lane] == want[lane], (
                f"wide n={n} b={b} mode={mode} grid={grid} {d} lane {lane}"
            )
        wide_cases += 1
    print(f"selftest: {wide_cases} wide-lane runs (1d+2d) match serial oracle")
    # Hierarchical grid-of-islands: distances bit-identical to the serial
    # oracle across random island grids, all directions, with and without
    # heterogeneous cluster pricing (pricing must never move distances).
    hier_cases = 0
    for _ in range(24):
        n = 20 + rng.next_below(120)
        ef = 1 + rng.next_below(4)
        g = uniform_random(n, ef, rng.next_u64())
        b = 1 + rng.next_below(20)
        roots = [rng.next_below(n) for _ in range(b)]
        want = [serial_bfs(g, r) for r in roots]
        islands = 1 + rng.next_below(4)
        per_island = 1 + rng.next_below(4)
        nodes = islands * per_island
        fanout = 1 + rng.next_below(4)
        d = ["topdown", "bottomup", "diropt"][rng.next_below(3)]
        topo = dgx2_cluster_topo(per_island) if rng.next_below(2) else None
        m = run_batch(g, nodes, fanout, roots, d, mode="hier",
                      grid=(islands, per_island), topo=topo)
        for lane in range(b):
            assert m["dist"][lane] == want[lane], (
                f"hier n={n} grid={islands}x{per_island} f={fanout} {d} lane {lane}"
            )
        hier_cases += 1
    print(f"selftest: {hier_cases} grid-of-islands runs match serial oracle")
    # A uniform topology (one island spanning every rank) must reproduce
    # the flat single-class pricing bit-for-bit.
    g = uniform_random(120, 3, 0xABCD)
    roots = [(i * 11 + 2) % 120 for i in range(8)]
    flatm = run_batch(g, 8, 2, roots, "topdown")
    unim = run_batch(g, 8, 2, roots, "topdown", topo=dict(
        name="uniform", per_island=1 << 30, intra=dict(DGX2), inter=dict(DGX2)))
    assert ([l["sim_comm"] for l in unim["levels"]]
            == [l["sim_comm"] for l in flatm["levels"]])
    assert all(l["inter_messages"] == 0 for l in unim["levels"])
    print("selftest: uniform topology reproduces flat pricing bit-for-bit")
    # Chunked == wide distance identity + amortization direction.
    g = uniform_random(150, 4, 0xC0FFEE)
    roots = [(i * 7 + 1) % 150 for i in range(130)]
    wide = run_batch(g, 4, 2, roots, "topdown", width_words=2)
    crounds = 0
    for k in range(0, 130, 64):
        cm = run_batch(g, 4, 2, roots[k:k + 64], "topdown")
        for j, lane_dist in enumerate(cm["dist"]):
            assert lane_dist == wide["dist"][k + j], f"chunk lane {k + j}"
        crounds += cm["sync_rounds"]
    assert wide["sync_rounds"] < crounds, (wide["sync_rounds"], crounds)
    print("selftest: one 130-wide batch == 3 chunked batches, fewer rounds")
    # Store codec: varint edge values, container round-trips (plain +
    # relabeled, odd block sizes), fingerprint sensitivity.
    for v in [0, 1, 127, 128, 129, 16383, 16384, (1 << 32) - 1, (1 << 64) - 1]:
        buf = bytearray()
        encode_varint(v, buf)
        got, pos = decode_varint(bytes(buf), 0)
        assert (got, pos) == (v, len(buf)), v
    codec_cases = 0
    for _ in range(12):
        n = 2 + rng.next_below(300)
        gg = uniform_random(n, 1 + rng.next_below(6), rng.next_u64())
        for bs in [1, 3, BLOCK_SIZE_DEFAULT]:
            img, _ = encode_store(gg, block_size=bs)
            dec, perm = decode_store(img)
            assert perm is None
            assert dec.offsets == gg.offsets and dec.edges == gg.edges, (n, bs)
            rimg, rold = encode_store(gg, relabel=True, block_size=bs)
            rdec, rgot = decode_store(rimg)
            assert rgot == rold
            nid = [0] * gg.n
            for newv, old in enumerate(rold):
                nid[old] = newv
            rg = apply_relabeling(gg, nid)
            assert rdec.offsets == rg.offsets and rdec.edges == rg.edges
            codec_cases += 1
    gw = weblike(512, 6, 0xB0B0_0006, strand_frac=0.18, strand_len=9)
    img, _ = encode_store(gw)
    assert decode_store(img)[0].edges == gw.edges
    fp = store_fingerprint(img)
    flipped = bytearray(img)
    flipped[40] ^= 0xFF  # first index entry
    assert store_fingerprint(bytes(flipped)) != fp, "fingerprint must move"
    print(f"selftest: {codec_cases} store codec round-trips (plain + relabeled)")


def validate_acceptance(report):
    """The invariants harness/protocol.rs::acceptance checks in Rust."""
    for c in report["configs"]:
        d = c["directions"]
        td, dopt = d["topdown"], d["diropt"]
        assert dopt["edges_inspected"] < td["edges_inspected"], c["nodes"]
        assert dopt["bottom_up_levels"] >= 1, c["nodes"]
        dense = max(td["per_level"], key=lambda l: l["frontier"])
        ddo = dopt["per_level"][dense["level"]]
        assert ddo["edges"] < dense["edges"], (c["nodes"], dense, ddo)
        assert ddo["direction"] == "bottomup", (c["nodes"], ddo)
    for entry in report["width_ablation"]:
        c = entry["chunked"]
        assert entry["reached_pairs"] == c["reached_pairs"], entry["mode"]
        if entry["width"] <= PROTOCOL["chunk"]:
            continue
        key = (entry["mode"], entry["width"])
        assert entry["sync_rounds"] < c["sync_rounds"], key
        assert entry["bytes"] < c["bytes"], key
    sim = report["serve_throughput"]["sim"]
    base, coal = sim["baseline"], sim["coalesced"]
    for name, mode in [("baseline", base), ("coalesced", coal)]:
        total = mode["completed"] + mode["rejected"] + mode["timed_out"]
        assert total == mode["offered"], (name, total, mode["offered"])
        assert mode["p50_us"] <= mode["p99_us"], name
    assert coal["qps"] > base["qps"], (base["qps"], coal["qps"])
    assert base["mean_width"] == 1.0, base["mean_width"]
    assert coal["mean_width"] > 1.0, coal["mean_width"]
    assert base["rejected"] > 0, "load point must overload the baseline"
    assert coal["rejected"] == 0, "coalesced service must keep up"
    assert coal["p50_us"] < base["p50_us"], (coal["p50_us"], base["p50_us"])
    st = report["storage"]
    assert st["compression_ratio"] >= 2.0, st["compression_ratio"]
    lc = st["load_counters"]
    assert lc["eager"]["edges"] == st["graph"]["edges"], lc["eager"]
    assert lc["cold_build"]["at_load"]["degree_entries"] > 0
    assert lc["cold_build"]["at_load"]["edges"] == 0
    warm0 = lc["warm_start"]["at_load"]
    assert warm0["degree_entries"] == 0 and warm0["edges"] == 0, warm0
    assert lc["warm_start"]["after_materialize"]["edges"] > 0
    assert st["warm_equals_cold"] and st["matches_in_memory"]
    twod0 = lc["two_d_cold"]["at_load"]
    assert twod0["edges"] == st["graph"]["edges"], twod0
    assert twod0["blocks"] == lc["eager"]["blocks"], twod0
    hier = report["hierarchical"]
    m1, m2, mh = (hier["modes"][k] for k in ["1d", "2d", "hier"])
    assert m1["reached_pairs"] == mh["reached_pairs"], "hier vs 1d pairs"
    assert m2["reached_pairs"] == mh["reached_pairs"], "hier vs 2d pairs"
    assert mh["sim_seconds"] < m1["sim_seconds"], (
        mh["sim_seconds"], m1["sim_seconds"])
    assert mh["sim_seconds"] < m2["sim_seconds"], (
        mh["sim_seconds"], m2["sim_seconds"])
    assert mh["inter_bytes"] < m1["inter_bytes"], (
        mh["inter_bytes"], m1["inter_bytes"])
    assert mh["intra_messages"] > 0 and mh["inter_messages"] > 0, mh
    fr = report["fault_recovery"]
    assert fr["equal_distances"] is True, "recovery moved a distance"
    fl = fr["faulted"]
    assert fl["matched"] >= 1, "no committed fault matched a live transfer"
    assert fl["retries"] >= 1 and fl["retry_bytes"] >= 1, fl
    assert fl["recovery_time"] > 0.0, fl
    assert fr["overhead_ratio"] > 1.0, fr["overhead_ratio"]
    kernel = report["kernel_ablation"]
    assert kernel, "kernel_ablation: no entries"
    for entry in kernel:
        key = (entry["mode"], entry["width"])
        assert entry["distances_equal"] is True, key
        s, c, n = entry["scalar"], entry["chunked"], entry["no_lrb"]
        assert c["words_touched"] < s["words_touched"], (key, c, s)
        assert c["tail_words"] < s["tail_words"], (key, c, s)
        assert s["words_skipped"] == 0, (key, s)
        assert c["words_skipped"] > 0, (key, c)
        assert c["dispatch_max_work"] < n["dispatch_max_work"], (key, c, n)
    print("acceptance invariants hold on the fresh report")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--selftest", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.selftest:
        selftest()
    report = engine_bench_report()
    validate_acceptance(report)
    for c in report["configs"]:
        d = c["directions"]
        print(f"p={c['nodes']}: edges td={d['topdown']['edges_inspected']} "
              f"bu={d['bottomup']['edges_inspected']} "
              f"do={d['diropt']['edges_inspected']} "
              f"(do bu-levels {d['diropt']['bottom_up_levels']}"
              f"/{d['diropt']['levels']})")
    for e in report["width_ablation"]:
        c = e["chunked"]
        print(f"{e['mode']} width={e['width']} (W={e['lane_words']}): "
              f"rounds {e['sync_rounds']} vs chunked {c['sync_rounds']}, "
              f"bytes {e['bytes']} vs chunked {c['bytes']}")
    sim = report["serve_throughput"]["sim"]
    for name in ["baseline", "coalesced"]:
        m = sim[name]
        print(f"serve {name}: completed {m['completed']}/{m['offered']} "
              f"rejected {m['rejected']} p50 {m['p50_us']}us "
              f"p99 {m['p99_us']}us qps {m['qps']:.0f} "
              f"mean width {m['mean_width']:.2f}")
    st = report["storage"]
    print(f"storage {st['graph']['name']}: v1 {st['v1_bytes']} -> "
          f"v2 {st['v2_bytes']} ({st['compression_ratio']:.2f}x, relabeled "
          f"{st['relabeled_ratio']:.2f}x), fingerprint {st['fingerprint']}, "
          f"warm at_load decodes "
          f"{st['load_counters']['warm_start']['at_load']['edges']} edges")
    h = report["hierarchical"]
    hm = h["modes"]["hier"]
    print(f"hier p={h['nodes']} ({h['islands']}, {h['net']}): "
          f"sim {hm['sim_seconds'] * 1e3:.3f}ms, "
          f"{h['speedup_vs_1d']:.2f}x vs 1d, {h['speedup_vs_2d']:.2f}x vs 2d, "
          f"inter bytes {hm['inter_bytes']} vs 1d "
          f"{h['modes']['1d']['inter_bytes']}")
    fr = report["fault_recovery"]
    fl = fr["faulted"]
    print(f"fault recovery p={fr['config']['nodes']}: "
          f"{fl['matched']}/{fl['injected']} faults fired, "
          f"{fl['retries']} retries ({fl['retry_bytes']} bytes), "
          f"recovery {fl['recovery_time'] * 1e6:.1f}us "
          f"({(fr['overhead_ratio'] - 1) * 100:.2f}% overhead), "
          f"distances equal: {fr['equal_distances']}")
    for e in report["kernel_ablation"]:
        s, c, n = e["scalar"], e["chunked"], e["no_lrb"]
        print(f"kernel {e['mode']} width={e['width']}: words "
              f"{c['words_touched']} vs scalar {s['words_touched']} "
              f"({s['words_touched'] / c['words_touched']:.2f}x), skipped "
              f"{c['words_skipped']}, max dispatch {c['dispatch_max_work']} "
              f"vs no-lrb {n['dispatch_max_work']}")
    if args.out:
        # Mirror write_engine_bench: the `measured` subtrees recorded
        # into the existing artifact by the load generator / kernel
        # bench are live-wallclock data the sim cannot regenerate —
        # carry them over.
        try:
            with open(args.out) as f:
                old = json.load(f)
        except (OSError, ValueError):
            old = {}
        try:
            measured = old["serve_throughput"]["measured"]
        except (KeyError, TypeError):
            measured = None
        if measured is not None:
            report["serve_throughput"]["measured"] = measured
        if isinstance(old, dict) and "kernel_ablation_measured" in old:
            report["kernel_ablation_measured"] = old["kernel_ablation_measured"]
        text = json.dumps(report, sort_keys=True, separators=(",", ":"))
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.setrecursionlimit(10000)
    main()
