//! Profiling driver for the §Perf pass: 30 back-to-back 16-node
//! traversals of a kron scale-16 graph — the workload behind the
//! before/after numbers in EXPERIMENTS.md §Perf.
//!
//! Usage: `cargo build --release --example prof_engine &&
//!         perf record -g ./target/release/examples/prof_engine &&
//!         perf report --no-children`

use butterfly_bfs::coordinator::{ButterflyBfs, EngineConfig};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};

fn main() {
    let (g, _) = kronecker(KroneckerParams::graph500(16, 16), 42);
    let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(16, 4));
    let t0 = std::time::Instant::now();
    for _ in 0..30 {
        engine.run(0);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "30 runs in {:.3} s  ({:.1} ms/run, dist[1]={})",
        dt,
        dt / 30.0 * 1e3,
        engine.dist()[1]
    );
}
