//! Profiling driver for the §Perf pass: 30 back-to-back 16-node
//! traversals of a kron scale-16 graph — the workload behind the
//! before/after numbers in EXPERIMENTS.md §Perf.
//!
//! Usage: `cargo build --release --example prof_engine &&
//!         perf record -g ./target/release/examples/prof_engine &&
//!         perf report --no-children`

use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};

fn main() {
    let (g, _) = kronecker(KroneckerParams::graph500(16, 16), 42);
    let plan = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4)).expect("valid plan");
    let mut session = plan.session();
    let t0 = std::time::Instant::now();
    let mut d1 = 0u32;
    for _ in 0..30 {
        d1 = session.run(0).expect("root in range").dist()[1];
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "30 runs in {:.3} s  ({:.1} ms/run, dist[1]={d1})",
        dt,
        dt / 30.0 * 1e3
    );
}
