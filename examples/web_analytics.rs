//! Domain example: web-graph analytics — the workload class the paper's
//! intro motivates (host-level web graphs, long-tail crawls).
//!
//! Builds the Webbase-2001-shaped analog (power-law web core + 400-vertex
//! crawl tail), then uses the distributed engine as a library to answer
//! analytics questions:
//!
//! * reachability + hop histogram from a seed page (BFS levels);
//! * the paper's §5 observation that the crawl tail starves parallelism —
//!   shown by per-level frontier sizes and the comm share;
//! * 2/3-hop neighborhood sizes (the intro's "people connected two or
//!   three hops away" query);
//! * s–t hop distances between seeds.
//!
//! Run: `cargo run --release --example web_analytics`

use butterfly_bfs::bfs::serial::INF;
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::weblike::{weblike, WeblikeParams};
use butterfly_bfs::graph::props;
use butterfly_bfs::harness::table::{count, Table};

fn main() {
    // The Webbase-2001 analog: web core + long crawl tail (DESIGN.md §7).
    let (g, _) = weblike(
        WeblikeParams { tail_len: 400, strand_frac: 0.15, strand_len: 25, ..WeblikeParams::core(1 << 16, 8) },
        0xB0B0_0001,
    );
    println!(
        "web graph: |V|={} |E|={} pseudo-diameter {}\n",
        count(g.num_vertices() as u64),
        count(g.num_edges()),
        props::pseudo_diameter(&g, 0)
    );

    let plan = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4))
        .expect("valid engine configuration");
    let mut session = plan.session();

    // --- Reachability + hop histogram from the seed page ---
    let seed_result = session.run(0).expect("root in range");
    session.assert_agreement().unwrap();
    let m = seed_result.metrics();
    println!("from seed page 0: reached {} pages in {} levels", count(m.reached), m.depth());
    let mut t = Table::new(&["hops", "pages", "frontier share"]);
    let reached = m.reached as f64;
    for (lvl, l) in m.levels.iter().enumerate().take(12) {
        t.row(vec![
            lvl.to_string(),
            count(l.frontier),
            format!("{:.2}%", l.frontier as f64 / reached * 100.0),
        ]);
    }
    if m.depth() > 12 {
        t.row(vec![format!("13..{}", m.depth()), "tail".into(), "~1 page/level".into()]);
    }
    println!("{}", t.render());

    // --- The crawl-tail pathology (§5 Webbase discussion) ---
    let tail_levels = m.levels.iter().filter(|l| l.frontier <= 2).count();
    println!(
        "crawl-tail effect: {} of {} levels have ≤2 active pages (synchronization-dominated; \
         comm share {:.1}%)\n",
        tail_levels,
        m.depth(),
        m.sim_comm_fraction() * 100.0
    );

    // --- k-hop neighborhoods (the intro's 2-3 hop query) ---
    let mut t = Table::new(&["seed", "1-hop", "2-hop", "3-hop"]);
    for seed in [0u32, 17, 4242] {
        let r = session.run(seed).expect("root in range");
        let d = r.dist();
        let khop = |k: u32| d.iter().filter(|&&x| x != INF && x <= k && x > 0).count() as u64;
        t.row(vec![
            seed.to_string(),
            count(khop(1)),
            count(khop(2)),
            count(khop(3)),
        ]);
    }
    println!("k-hop neighborhood sizes:\n{}", t.render());

    // --- s-t hop distances (the seed result owns its distances, so the
    // k-hop queries above did not disturb it) ---
    let d = seed_result.dist();
    let mut t = Table::new(&["target page", "hops from seed 0"]);
    for target in [1u32, 1000, 65_535, 65_935] {
        let hops = d[target as usize];
        t.row(vec![
            target.to_string(),
            if hops == INF { "unreachable".into() } else { hops.to_string() },
        ]);
    }
    println!("s–t distances (65935 = end of the crawl tail):\n{}", t.render());
}
