//! **End-to-end validation driver** (DESIGN.md §5 E2E): the full system on
//! a real workload, proving all layers compose:
//!
//! 1. generate the `GAP_kron`-shaped headline workload (Graph500
//!    Kronecker), run the ETL, partition 1D across 16 simulated nodes;
//! 2. run distributed ButterFly BFS with the paper's root protocol on the
//!    **native** backend, reporting wall + simulated DGX-2 times, GTEPS,
//!    and the per-phase split (the paper's headline metrics);
//! 3. run the same traversal through the **XLA backend** — the
//!    AOT-compiled JAX/Pallas frontier step via PJRT — on a demo-scale
//!    graph and cross-check distances against both the native engine and
//!    the serial oracle;
//! 4. extrapolate the scale-29/ef-8 headline number through the device
//!    model and print the projected GTEPS next to the paper's 300+.
//!
//! Results of a recorded run live in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_dgx2_traversal`
//! (scale via `BBFS_E2E_SCALE`, default 18).

use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::graph::props;
use butterfly_bfs::harness::roots::{run_protocol, RootProtocol};
use butterfly_bfs::harness::table::{count, f2, ms, Table};
use butterfly_bfs::partition::one_d::partition_1d;
use butterfly_bfs::runtime::{find_artifact, variant_for, FrontierStep, XlaFrontierBackend};
use butterfly_bfs::util::stats::gteps;
use std::sync::Arc;

fn main() {
    let scale: u32 = std::env::var("BBFS_E2E_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(18);
    println!("=== E2E: ButterFly BFS on a DGX-2-shaped 16-node system ===\n");

    // ---- 1. ETL + partition ----
    let t0 = std::time::Instant::now();
    let (g, etl) = kronecker(KroneckerParams::graph500(scale, 8), 0xE2E);
    println!(
        "[etl] kron scale {scale} ef 8: |V|={} |E|={} ({} self-loops, {} dups removed) in {:.1} s",
        count(g.num_vertices() as u64),
        count(g.num_edges()),
        count(etl.self_loops),
        count(etl.duplicates),
        t0.elapsed().as_secs_f64()
    );
    let part = partition_1d(&g, 16);
    println!(
        "[partition] 16 nodes, edge imbalance {:.3} (1.0 = perfect)",
        part.imbalance(&g)
    );
    let cc = props::connected_components(&g);
    println!(
        "[props] largest component {:.1}% of vertices (paper: 90–95%)\n",
        cc.largest_fraction() * 100.0
    );

    // ---- 2. Native-backend traversal, paper protocol ----
    let proto = RootProtocol::from_env();
    let plan = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4)).expect("valid plan");
    let mut session = plan.session();
    let mut wall_times = Vec::new();
    let (sim_mean, _) = run_protocol(&g, &proto, |r| {
        let m = session.run_metrics_only(r).expect("protocol root in range");
        wall_times.push(m.wall_seconds);
        m.sim_seconds()
    });
    session.assert_agreement().expect("distance agreement");
    // Showcase root: the max-degree vertex (guaranteed in the largest
    // component; random roots can land on isolated Kronecker vertices).
    let showcase_root = (0..g.num_vertices() as u32)
        .max_by_key(|&v| g.degree(v))
        .unwrap();
    let m = session
        .run_metrics_only(showcase_root)
        .expect("root in range");
    println!("[native] {} roots (trim {}):", proto.num_roots, proto.trim);
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["sim DGX-2 time (trimmed mean)".into(), format!("{} ms", ms(sim_mean))]);
    t.row(vec!["sim GTEPS (|E|/t)".into(), f2(gteps(g.num_edges(), sim_mean))]);
    t.row(vec![
        "wall time / root (this host)".into(),
        format!("{} ms", ms(wall_times.iter().sum::<f64>() / wall_times.len() as f64)),
    ]);
    t.row(vec![
        format!("BFS depth (root {showcase_root})"),
        m.depth().to_string(),
    ]);
    t.row(vec!["comm share of sim time".into(), format!("{:.1}%", m.sim_comm_fraction() * 100.0)]);
    t.row(vec!["messages / traversal".into(), count(m.messages())]);
    t.row(vec!["bytes / traversal".into(), count(m.bytes())]);
    println!("{}", t.render());

    // ---- 3. XLA backend cross-check (three-layer compose proof) ----
    let demo_v = 1500usize;
    match variant_for(demo_v).and_then(find_artifact) {
        Some(ref path) => {
            let key = variant_for(demo_v).unwrap();
            let step = Arc::new(
                FrontierStep::load(&path, key.num_vertices).expect("artifact compiles"),
            );
            let (dg, _) = kronecker(KroneckerParams::graph500(10, 8), 0xE2E + 1);
            let cfg = EngineConfig::dgx2(8, 4);
            let dpart = partition_1d(&dg, cfg.num_nodes);
            let backends =
                XlaFrontierBackend::for_slabs(Arc::clone(&step), &dpart.slabs(&dg)).unwrap();
            // One plan, two sessions (XLA + native backends) — the
            // plan/session split at work.
            let dplan = TraversalPlan::build(&dg, cfg).expect("valid plan");
            let mut xla_session = dplan.session_with_backends(backends).unwrap();
            let mut native_session = dplan.session();
            let t0 = std::time::Instant::now();
            let rx = xla_session.run(0).expect("root in range");
            let xla_wall = t0.elapsed().as_secs_f64();
            let rn = native_session.run(0).expect("root in range");
            xla_session.assert_agreement().unwrap();
            assert_eq!(rx.dist(), rn.dist());
            assert_eq!(rx.dist(), &serial_bfs(&dg, 0)[..]);
            println!(
                "[xla] PJRT frontier step (v{} artifact, 8 nodes): reached {} in {} levels, \
                 wall {:.1} ms — distances == native == serial ✓\n",
                step.num_vertices,
                count(rx.reached()),
                rx.depth(),
                xla_wall * 1e3
            );
        }
        None => {
            println!("[xla] artifacts not built — run `make artifacts` first (skipping)\n");
        }
    }

    // ---- 4. Headline projection: scale-29 ef-8 Kronecker ----
    // Apply the measured per-edge device cost and per-level overheads of
    // *this* run (showcase root, in the largest component) to the paper's
    // scale-29 input (512 M vertices, 8 B directed = 16 B symmetrized
    // arcs; same LCC fraction and depth class as our analog).
    let edges_29: u64 = 2 * 8 * (1u64 << 29);
    let examined_frac = m.edges_examined() as f64 / g.num_edges() as f64;
    let per_edge = (m.sim_seconds()
        - m.levels.iter().map(|l| l.sim_comm).sum::<f64>())
        / m.edges_examined().max(1) as f64;
    let per_level_comm = m.levels.iter().map(|l| l.sim_comm).sum::<f64>() / m.depth() as f64;
    // Kron diameter stays ~5-7 across scales; comm payload grows with V.
    let projected = per_edge * edges_29 as f64 * examined_frac
        + per_level_comm * ((1u64 << 29) as f64 / g.num_vertices() as f64) * m.depth() as f64;
    println!(
        "[headline] projected scale-29 ef-8 traversal: {:.1} ms -> {:.0} GTEPS (|E|/t convention; \
         paper reports 300+)",
        projected * 1e3,
        gteps(edges_29, projected)
    );
    println!("\nE2E complete: all layers verified.");
}
