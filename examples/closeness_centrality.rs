//! Domain example: sampled closeness centrality — an APSP-class analytic.
//!
//! The paper's motivation for keeping a fast *top-down* traversal (rather
//! than relying on direction optimization) is exactly this workload class:
//! "direction optimizing BFS does not apply to all problems requiring a
//! BFS traversal. For example, an APSP type of problem such as betweenness
//! centrality might need to find all paths." Closeness centrality runs one
//! full BFS per sample vertex and aggregates distances — hundreds of
//! back-to-back traversals through the same engine, the regime where
//! per-traversal synchronization overhead (the butterfly's target) is the
//! whole game.
//!
//! Run: `cargo run --release --example closeness_centrality`

use butterfly_bfs::bfs::serial::INF;
use butterfly_bfs::coordinator::{ButterflyBfs, EngineConfig};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::harness::table::{count, f3, Table};
use butterfly_bfs::util::prng::Xoshiro256StarStar;

fn main() {
    let (g, _) = kronecker(KroneckerParams::graph500(15, 16), 0xCC);
    println!(
        "graph: |V|={} |E|={}\n",
        count(g.num_vertices() as u64),
        count(g.num_edges())
    );
    let mut engine = ButterflyBfs::new(&g, EngineConfig::dgx2(16, 4));

    // Sample source vertices (same trick as the root protocol: prefer
    // non-isolated sources).
    let mut rng = Xoshiro256StarStar::seed_from_u64(7);
    let samples = 64;
    let n = g.num_vertices();
    let mut sources = Vec::with_capacity(samples);
    while sources.len() < samples {
        let v = rng.next_usize(n) as u32;
        if g.degree(v) > 0 {
            sources.push(v);
        }
    }

    // One full traversal per source; accumulate inverse farness for every
    // reachable vertex (Wasserman–Faust normalization per source sample).
    let t0 = std::time::Instant::now();
    let mut sum_dist = vec![0u64; n];
    let mut times_reached = vec![0u32; n];
    let mut sim_total = 0.0;
    let mut edges_total = 0u64;
    for &s in &sources {
        let m = engine.run(s);
        sim_total += m.sim_seconds();
        edges_total += m.edges_examined();
        for (v, &d) in engine.dist().iter().enumerate() {
            if d != INF {
                sum_dist[v] += d as u64;
                times_reached[v] += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{} traversals: wall {:.2} s, simulated DGX-2 {:.2} ms total, {} edges examined",
        samples,
        wall,
        sim_total * 1e3,
        count(edges_total)
    );

    // Closeness estimate: reached_count / sum_of_distances.
    let mut ranked: Vec<(u32, f64)> = (0..n as u32)
        .filter(|&v| times_reached[v as usize] as usize == samples && sum_dist[v as usize] > 0)
        .map(|v| {
            (
                v,
                times_reached[v as usize] as f64 / sum_dist[v as usize] as f64,
            )
        })
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    let mut t = Table::new(&["rank", "vertex", "closeness", "degree"]);
    for (i, &(v, c)) in ranked.iter().take(10).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            v.to_string(),
            f3(c),
            g.degree(v).to_string(),
        ]);
    }
    println!("top-10 closeness (sampled):\n{}", t.render());

    // Sanity: high closeness should correlate with high degree on
    // Kronecker graphs (hubs are central).
    let top_degree_mean: f64 = ranked
        .iter()
        .take(10)
        .map(|&(v, _)| g.degree(v) as f64)
        .sum::<f64>()
        / 10.0;
    let global_mean = g.num_edges() as f64 / n as f64;
    println!(
        "top-10 mean degree {top_degree_mean:.0} vs graph mean {global_mean:.1} \
         (hubs are central ✓)"
    );
    assert!(top_degree_mean > global_mean);
}
