//! Domain example: sampled closeness centrality — an APSP-class analytic,
//! driven by one **256-wide** batched multi-source BFS.
//!
//! The paper's motivation for keeping a fast *top-down* traversal (rather
//! than relying on direction optimization) is exactly this workload class:
//! "direction optimizing BFS does not apply to all problems requiring a
//! BFS traversal. For example, an APSP type of problem such as betweenness
//! centrality might need to find all paths." Closeness centrality needs
//! one full BFS per sample vertex — and with the const-generic wide lane
//! masks all 256 samples advance bit-parallel through *one* butterfly
//! exchange per level. Before lane widening this took four chunked
//! 64-root batches: four level loops, four exchange sequences. The
//! example runs both and prints what the single wide batch saves — sync
//! rounds (the headline: one exchange sequence serves 4× the roots) and
//! exchange bytes (the cohort-factored negotiated encoding never prices
//! worse than the chunks, and coalescing lanes price better).
//!
//! Run: `cargo run --release --example closeness_centrality`

use butterfly_bfs::bfs::msbfs::sample_batch_roots;
use butterfly_bfs::bfs::serial::INF;
use butterfly_bfs::coordinator::{BatchWidth, EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::harness::table::{count, f2, f3, Table};

fn main() {
    let (g, _) = kronecker(KroneckerParams::graph500(14, 16), 0xCC);
    let n = g.num_vertices();
    println!(
        "graph: |V|={} |E|={}\n",
        count(n as u64),
        count(g.num_edges())
    );
    let samples = 256usize;
    let cfg = EngineConfig {
        batch_width: BatchWidth::for_lanes(samples)
            .expect("sample count is within the 512-lane limit"),
        ..EngineConfig::dgx2(16, 4)
    };
    let plan = TraversalPlan::build(&g, cfg).expect("valid engine configuration");
    let mut session = plan.session();

    // Sample source vertices (prefer non-isolated, duplicates allowed —
    // each lane is an independent traversal).
    let sources = sample_batch_roots(&g, samples, 7);

    // One batched traversal: all 256 sources in lock-step, four mask
    // words per vertex, one exchange per level.
    let t0 = std::time::Instant::now();
    let batch = session.run_batch(&sources).expect("valid batch");
    let wall = t0.elapsed().as_secs_f64();
    session.assert_batch_agreement().expect("node agreement");
    let bm = batch.metrics();
    println!(
        "{} traversals in ONE batch ({} mask words, {} lanes/exchange): \
         wall {:.2} s, simulated DGX-2 {:.2} ms, {} levels, {} sync rounds, {} bytes",
        samples,
        bm.lane_words,
        bm.lanes_per_exchange(),
        wall,
        bm.sim_seconds() * 1e3,
        bm.depth(),
        bm.sync_rounds,
        count(bm.bytes())
    );

    // Accumulate inverse farness for every reachable vertex
    // (Wasserman–Faust normalization per source sample).
    let mut sum_dist = vec![0u64; n];
    let mut times_reached = vec![0u32; n];
    for lane in 0..samples {
        for (v, &d) in batch.dist(lane).iter().enumerate() {
            if d != INF {
                sum_dist[v] += d as u64;
                times_reached[v] += 1;
            }
        }
    }

    // What the same 256 sources cost as four chunked 64-root batches —
    // the pre-widening execution (single-word lane masks, default width).
    let mut chunked = TraversalPlan::build(&g, EngineConfig::dgx2(16, 4))
        .expect("valid engine configuration")
        .session();
    let (mut c_rounds, mut c_bytes, mut c_sim) = (0u64, 0u64, 0f64);
    for chunk in sources.chunks(64) {
        let cm = chunked
            .run_batch_metrics_only(chunk)
            .expect("valid chunk");
        c_rounds += cm.sync_rounds;
        c_bytes += cm.bytes();
        c_sim += cm.sim_seconds();
    }
    println!(
        "chunked 4 x 64 baseline: simulated {:.2} ms, {} sync rounds, {} bytes",
        c_sim * 1e3,
        c_rounds,
        count(c_bytes)
    );
    println!(
        "wide-lane saving: {}x fewer sync rounds, {} fewer bytes ({}x), {}x sim speedup\n",
        f2(c_rounds as f64 / bm.sync_rounds.max(1) as f64),
        count(c_bytes.saturating_sub(bm.bytes())),
        f2(c_bytes as f64 / bm.bytes().max(1) as f64),
        f2(c_sim / bm.sim_seconds().max(1e-12))
    );

    // Closeness estimate: reached_count / sum_of_distances. A majority
    // filter (rather than requiring every lane) keeps the ranking robust
    // even if a sampled source lands outside the giant component.
    let mut ranked: Vec<(u32, f64)> = (0..n as u32)
        .filter(|&v| {
            times_reached[v as usize] as usize * 2 > samples && sum_dist[v as usize] > 0
        })
        .map(|v| {
            (
                v,
                times_reached[v as usize] as f64 / sum_dist[v as usize] as f64,
            )
        })
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    let mut t = Table::new(&["rank", "vertex", "closeness", "degree"]);
    for (i, &(v, c)) in ranked.iter().take(10).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            v.to_string(),
            f3(c),
            g.degree(v).to_string(),
        ]);
    }
    println!("top-10 closeness (sampled, 256 sources):\n{}", t.render());

    // Sanity: high closeness should correlate with high degree on
    // Kronecker graphs (hubs are central).
    let top_degree_mean: f64 = ranked
        .iter()
        .take(10)
        .map(|&(v, _)| g.degree(v) as f64)
        .sum::<f64>()
        / 10.0;
    let global_mean = g.num_edges() as f64 / n as f64;
    println!(
        "top-10 mean degree {top_degree_mean:.0} vs graph mean {global_mean:.1} \
         (hubs are central ✓)"
    );
    assert!(top_degree_mean > global_mean);

    // The wide-lane claims hold outside the test suite too: one wide
    // batch runs strictly fewer sync rounds and ships no more bytes than
    // its four 64-root chunks (the protocol's acceptance invariant).
    assert_eq!(bm.lane_words, 4);
    assert!(bm.sync_rounds < c_rounds, "wide batch must run fewer rounds");
    assert!(bm.bytes() <= c_bytes, "wide batch must not ship more bytes");
    assert!(
        bm.sim_seconds() < c_sim,
        "wide batch must be faster on the simulated clock"
    );
}
