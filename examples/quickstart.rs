//! Quickstart: generate a Graph500 Kronecker graph, run distributed
//! ButterFly BFS across 16 simulated compute nodes, and verify against the
//! serial oracle.
//!
//! Run: `cargo run --release --example quickstart`

use butterfly_bfs::bfs::serial::serial_bfs;
use butterfly_bfs::coordinator::{EngineConfig, TraversalPlan};
use butterfly_bfs::graph::gen::kronecker::{kronecker, KroneckerParams};
use butterfly_bfs::harness::table::count;

fn main() {
    // 1. A Graph500-style Kronecker graph: 2^16 vertices, edge factor 16.
    let (graph, etl) = kronecker(KroneckerParams::graph500(16, 16), 42);
    println!(
        "graph: |V|={}, |E|={} (ETL removed {} self-loops, {} duplicates)",
        count(graph.num_vertices() as u64),
        count(graph.num_edges()),
        etl.self_loops,
        etl.duplicates
    );

    // 2. Build the immutable plan once — the paper's headline config
    //    (16 nodes, fanout 4, DGX-2 interconnect model) — then open a
    //    cheap query session over it. The plan is `Arc`-shareable, so a
    //    service would hand one plan to many concurrent sessions.
    let plan = TraversalPlan::build(&graph, EngineConfig::dgx2(16, 4))
        .expect("valid engine configuration");
    println!(
        "plan: 16 nodes, {} sync rounds/level, {} messages/level",
        plan.schedule().depth(),
        plan.schedule().total_messages()
    );
    let mut session = plan.session();

    // 3. Traverse. The result owns its distances and metrics; invalid
    //    roots would surface as a typed `QueryError`, not a panic.
    let result = session.run(0).expect("root in range");
    let metrics = result.metrics();
    println!(
        "traversal: reached {} vertices in {} levels, examined {} edges",
        count(metrics.reached),
        metrics.depth(),
        count(metrics.edges_examined())
    );
    println!(
        "wallclock {:.1} ms | simulated DGX-2 time {:.3} ms -> {:.1} GTEPS (|E|/t), {:.1}% comm",
        metrics.wall_seconds * 1e3,
        metrics.sim_seconds() * 1e3,
        metrics.sim_gteps(),
        metrics.sim_comm_fraction() * 100.0
    );

    // 4. Verify: every node's distance array equals the serial oracle.
    session.assert_agreement().expect("all nodes agree");
    assert_eq!(result.dist(), &serial_bfs(&graph, 0)[..]);
    println!("verified: distributed result == serial BFS ✓");
}
