//! Reproduces **Figs. 1 and 2** as text: the butterfly network's round-by-
//! round knowledge propagation for fanout 1 and fanout 4 over 16 compute
//! nodes (the (b)–(f) subfigure sequence), the non-power-of-two hotspot of
//! Fig 1(f), and the §3 cost comparison against all-to-all.
//!
//! Run: `cargo run --release --example comm_pattern_analysis`

use butterfly_bfs::comm::analysis::{comm_costs, propagate_knowledge};
use butterfly_bfs::comm::{Butterfly, CommPattern, ConcurrentAllToAll, IterativeAllToAll};
use butterfly_bfs::harness::table::Table;
use butterfly_bfs::net::model::NetModel;
use butterfly_bfs::net::sim::simulate_uniform;

fn knowledge_string(k: u128, cn: u32) -> String {
    (0..cn)
        .map(|g| if k >> g & 1 == 1 { 'x' } else { '.' })
        .collect()
}

fn show_butterfly(fanout: u32, cn: u32) {
    let bf = Butterfly::new(fanout);
    let s = bf.schedule(cn);
    println!(
        "butterfly fanout {fanout}, {cn} nodes: {} rounds, {} messages",
        s.depth(),
        s.total_messages()
    );
    // Recreate the (b)-(f) panels: node 0's knowledge after each round.
    let mut know: Vec<u128> = (0..cn).map(|g| 1u128 << g).collect();
    println!("  node 0 knows: {}   (start — Fig (b))", knowledge_string(know[0], cn));
    for (i, round) in s.rounds.iter().enumerate() {
        let snap = know.clone();
        for t in round {
            know[t.dst as usize] |= snap[t.src as usize];
        }
        println!(
            "  node 0 knows: {}   (after round {i})",
            knowledge_string(know[0], cn)
        );
    }
    let done = propagate_knowledge(&s);
    assert!(done.iter().all(|&k| k == (1u128 << cn) - 1));
    println!("  all {cn} nodes hold all frontiers ✓\n");
}

fn main() {
    println!("== Fig 1: butterfly, fanout 1, 16 nodes ==");
    show_butterfly(1, 16);

    println!("== Fig 2: butterfly, fanout 4, 16 nodes ==");
    show_butterfly(4, 16);

    println!("== Fig 1(f): 9 nodes, fanout 1 — the last-round hotspot ==");
    let s9 = Butterfly::new(1).schedule(9);
    for (i, round) in s9.rounds.iter().enumerate() {
        let from8 = round.iter().filter(|t| t.src == 8).count();
        println!(
            "  round {i}: {} transfers, {} sent by node 8",
            round.len(),
            from8
        );
    }
    println!();

    println!("== §3 cost comparison (16 nodes, 1 MB payloads, DGX-2 model) ==");
    let net = NetModel::dgx2();
    let payload = 1u64 << 20;
    let mut t = Table::new(&["pattern", "rounds", "messages", "buffer MB", "sim ms"]);
    let pats: Vec<(&str, Box<dyn CommPattern>)> = vec![
        ("butterfly f=1", Box::new(Butterfly::new(1))),
        ("butterfly f=4", Box::new(Butterfly::new(4))),
        ("all-to-all concurrent", Box::new(ConcurrentAllToAll)),
        ("all-to-all iterative", Box::new(IterativeAllToAll)),
    ];
    for (name, p) in pats {
        let s = p.schedule(16);
        let c = comm_costs(&s, payload);
        let sim = simulate_uniform(&s, &net, payload);
        t.row(vec![
            name.into(),
            c.rounds.to_string(),
            c.messages.to_string(),
            format!("{:.1}", c.buffer_bytes as f64 / (1 << 20) as f64),
            format!("{:.3}", sim.total() * 1e3),
        ]);
    }
    println!("{}", t.render());
}
